"""Dynamic repartitioning: the slice inventory as an online decision variable.

Covers the subsystem end to end:
  * the MIG-style profile lattice (pow2 validation, split/merge legality,
    inference from an existing inventory);
  * the buddy layout (deterministic adoption, sibling detection, bounded
    canonical ids under split/merge cycles);
  * the fragmentation index and the ``frag_aware`` announcement ordering;
  * ``DeadWindowRegistry.drop_slice`` (canonical-id rebirth starts clean);
  * byte-identity of StaticInventory with the repartition subsystem off —
    on the simulator (serial AND pipelined) and on a service soak;
  * FragmentationAware recovering goodput on a fragmented inventory;
  * EnergyAware consolidate-and-gate with the energy proxy and ψ_energy;
  * the drain-first safety protocol (graceful drain, forced revocation
    through the slice-failure path with ``lost`` commit rows);
  * crash-checkpoint byte-identical resume ACROSS a repartition boundary;
  * pipelined speculation staying byte-identical to serial rounds when
    the slice count changes mid-stream;
  * heterogeneous ``min_capacity`` workload generation.

CI runs this file across seeds via JASDA_REPARTITION_SEED (see the
repartition job in .github/workflows/ci.yml).
"""
import os
import pickle

import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.core import (EnergyAware, EnergyModel, FaultEvent, FaultPlan,
                        FragmentationAware, JasdaScheduler, Move, Policy,
                        ProfileLattice, RepartitionCoordinator,
                        RepartitionPolicy, RepartitionState, SimConfig,
                        SliceProfile, SliceSpec, StaticInventory,
                        fragmentation_index, make_workload, simulate)
from repro.core.faults import SCHEDULER_CRASH
from repro.core.scoring import ScoringPolicy
from repro.core.windows import (DeadWindowRegistry, SliceTimeline,
                                WindowPolicy, announce_windows)
from repro.service import (AcceptAll, JasdaService, PoissonArrivals,
                           ServiceConfig)

SEED = int(os.environ.get("JASDA_REPARTITION_SEED", "0"))
GB = 1 << 30


def _packed(cap_gb=5):
    """Two 4-chip slices: big jobs fit."""
    return [SliceSpec("big0", 4 * cap_gb * GB, n_chips=4),
            SliceSpec("big1", 4 * cap_gb * GB, n_chips=4)]


def _fragmented(cap_gb=5):
    """Eight 1-chip slices: same pod, big jobs strand."""
    return [SliceSpec(f"f{k}", cap_gb * GB, n_chips=1) for k in range(8)]


def _hetero_workload(n=30, seed=SEED + 3):
    """Workload where ~60% of jobs need more than one 5 GB chip."""
    return make_workload(n, seed=seed, arrival_rate=0.5,
                         work_range=(5.0, 40.0), mem_range_gb=(1.0, 4.0),
                         min_capacity_fraction=0.6,
                         min_capacity_range_gb=(12.0, 18.0))


def _commit_rows(sched):
    return [(r.status, r.job_id, r.slice_id, r.t_start, r.t_end, r.score)
            for r in sched.commit_log]


def _sim_key(r):
    return (_commit_rows(r.scheduler), r.jct_per_job, r.n_finished,
            r.total_score)


# ---------------------------------------------------------------------------
# profile lattice
# ---------------------------------------------------------------------------

class TestProfileLattice:
    def test_default_ladder(self):
        lat = ProfileLattice.default(max_chips=8)
        assert [p.n_chips for p in lat.profiles] == [1, 2, 4, 8]
        assert lat.can_split(4) and lat.can_merge(4)
        assert not lat.can_split(1)  # no half-chip profile
        assert not lat.can_merge(8)  # no 16-chip profile
        assert lat.max_power == lat.profile_for(8).power_watts
        with pytest.raises(KeyError):
            lat.profile_for(3)

    def test_profile_rejects_non_pow2(self):
        with pytest.raises(ValueError):
            SliceProfile(n_chips=3, capacity_bytes=GB, power_watts=1.0,
                         idle_watts=0.1)

    def test_infer_from_inventory(self):
        lat = ProfileLattice.infer(_fragmented())
        assert [p.n_chips for p in lat.profiles] == [1, 2, 4, 8]
        assert lat.profile_for(4).capacity_bytes == pytest.approx(20 * GB)
        # inconsistent per-chip capacity is a hard error
        bad = [SliceSpec("a", 5 * GB, n_chips=1), SliceSpec("b", 7 * GB, n_chips=1)]
        with pytest.raises(ValueError):
            ProfileLattice.infer(bad)

    def test_spec_for_inherits_template_hardware(self):
        lat = ProfileLattice.default(max_chips=4)
        tmpl = SliceSpec("t", 5 * GB, n_chips=1, flops_per_s=3.0, hbm_bw=2.0)
        s = lat.spec_for("p0c2", 2, template=tmpl)
        assert (s.slice_id, s.n_chips) == ("p0c2", 2)
        assert s.capacity_bytes == lat.profile_for(2).capacity_bytes
        assert (s.flops_per_s, s.hbm_bw) == (3.0, 2.0)


# ---------------------------------------------------------------------------
# buddy layout
# ---------------------------------------------------------------------------

class TestBuddyLayout:
    def test_adopt_is_deterministic_and_aligned(self):
        specs = _packed() + []
        s1 = RepartitionState.adopt(specs, ProfileLattice.infer(specs))
        s2 = RepartitionState.adopt(list(reversed(specs)),
                                    ProfileLattice.infer(specs))
        assert s1.intervals == s2.intervals
        for off, n in s1.intervals.values():
            assert off % n == 0

    def test_split_merge_round_trip_bounds_ids(self):
        specs = [SliceSpec("root", 20 * GB, n_chips=4)]
        lat = ProfileLattice.infer(specs)
        st = RepartitionState.adopt(specs, lat)
        (a, _), (b, _) = st.apply_split("root")
        assert {a, b} == {"p0c2", "p2c2"}
        assert st.buddy_of(a) == b
        parent, n = st.apply_merge(a, b)
        assert (parent, n) == ("p0c4", 4)
        # a second cycle rebuilds the SAME ids — no unbounded growth
        (a2, _), (b2, _) = st.apply_split(parent)
        assert {a2, b2} == {a, b}

    def test_merge_rejects_non_siblings(self):
        specs = [SliceSpec(f"f{k}", 5 * GB, n_chips=1) for k in range(4)]
        lat = ProfileLattice.infer(specs)
        st = RepartitionState.adopt(specs, lat)
        by_off = {off: sid for sid, (off, _) in st.intervals.items()}
        with pytest.raises(ValueError):
            st.apply_merge(by_off[1], by_off[2])  # adjacent but not buddies

    def test_mergeable_pairs_largest_first_and_live_filter(self):
        specs = _fragmented()[:4] + [SliceSpec("m0", 10 * GB, n_chips=2),
                                     SliceSpec("m1", 10 * GB, n_chips=2)]
        lat = ProfileLattice.infer(specs)
        st = RepartitionState.adopt(specs, lat)
        pairs = st.mergeable_pairs(lat)
        assert pairs and st.intervals[pairs[0][0]][1] == 2  # 2-chip pair first
        # a slice missing from the live pool cannot merge
        live = {s.slice_id for s in specs} - {"m0"}
        assert all("m0" not in p for p in st.mergeable_pairs(lat, live=live))


# ---------------------------------------------------------------------------
# fragmentation metric + frag_aware window ordering
# ---------------------------------------------------------------------------

class TestFragmentation:
    def test_index_is_stranded_work_fraction(self):
        caps = [5 * GB, 5 * GB]
        assert fragmentation_index(caps, []) == 0.0
        assert fragmentation_index(caps, [(10.0, 4 * GB)]) == 0.0
        assert fragmentation_index(caps, [(10.0, 8 * GB)]) == 1.0
        assert fragmentation_index(
            caps, [(30.0, 8 * GB), (10.0, GB)]) == pytest.approx(0.75)

    def _timelines(self):
        return {s.slice_id: SliceTimeline(s)
                for s in [SliceSpec("c20", 20 * GB), SliceSpec("c10", 10 * GB),
                          SliceSpec("c5", 5 * GB)]}

    def test_frag_aware_orders_by_tight_fit(self):
        pol = WindowPolicy(kind="frag_aware", horizon=50.0)
        # 9 GB demand: c10 is the tightest fit (1 GB slack); c5 serves no
        # floor and competes on raw capacity (5 GB), still ahead of the
        # loose-fitting c20 (11 GB slack)
        ws = announce_windows(self._timelines(), 0.0, pol, demand=[9 * GB])
        assert [w.slice_id for w in ws] == ["c10", "c5", "c20"]
        # no demand: capacity-ascending (the fit degenerates to capacity)
        ws = announce_windows(self._timelines(), 0.0, pol)
        assert [w.slice_id for w in ws] == ["c5", "c10", "c20"]

    def test_other_kinds_ignore_demand(self):
        for kind in ("earliest", "largest", "best_fit", "slack"):
            pol = WindowPolicy(kind=kind, horizon=50.0)
            with_d = announce_windows(self._timelines(), 0.0, pol,
                                      demand=[9 * GB])
            without = announce_windows(self._timelines(), 0.0, pol)
            assert [w.slice_id for w in with_d] == [w.slice_id for w in without]


class TestDeadWindowDropSlice:
    def test_drop_slice_retires_all_entries(self):
        reg = DeadWindowRegistry()
        reg.add("a", 1.0, 10.0)
        reg.add("a", 5.0, 10.0)
        reg.add("b", 1.0, 10.0)
        assert reg.drop_slice("a") == 2
        assert not reg.suppressed("a", 1.0) and not reg.suppressed("a", 5.0)
        assert reg.suppressed("b", 1.0)  # untouched
        assert reg.drop_slice("a") == 0  # idempotent


# ---------------------------------------------------------------------------
# StaticInventory byte-identity
# ---------------------------------------------------------------------------

class TestStaticIdentity:
    @pytest.mark.parametrize("pipeline", [False, True])
    def test_simulate_identical_with_and_without_subsystem(self, pipeline):
        agents = lambda: _hetero_workload(14)  # noqa: E731
        base = SimConfig(t_end=250.0, seed=SEED, pipeline=pipeline)
        r0 = simulate(JasdaScheduler(_packed()), agents(), base)
        r1 = simulate(JasdaScheduler(_packed()), agents(),
                      SimConfig(t_end=250.0, seed=SEED, pipeline=pipeline,
                                repartition=StaticInventory()))
        assert _sim_key(r0) == _sim_key(r1)
        assert r1.repartition.stats()["n_splits"] == 0
        assert r1.repartition.stats()["n_forced"] == 0

    def test_service_soak_identical_with_and_without_subsystem(self):
        def soak(repartition):
            arr = PoissonArrivals(0.5, seed=SEED, work_range=(8.0, 40.0),
                                  mem_range_gb=(1.0, 12.0))
            cfg = ServiceConfig(t_end=120.0, seed=SEED,
                                repartition=repartition)
            svc = JasdaService(
                JasdaScheduler(_packed() + _fragmented()[:4]), arr,
                config=cfg, admission=AcceptAll())
            stats = svc.run()
            return ([(r.round, r.t, r.variant_id, r.job_id, r.slice_id)
                     for r in svc.award_log], stats)

        assert soak(None) == soak(StaticInventory())


# ---------------------------------------------------------------------------
# FragmentationAware: goodput recovery
# ---------------------------------------------------------------------------

class TestFragmentationAware:
    def test_recovers_goodput_on_fragmented_inventory(self):
        cfg = lambda pol: SimConfig(t_end=300.0, seed=SEED,  # noqa: E731
                                    repartition=pol)
        r_static = simulate(JasdaScheduler(_fragmented()),
                            _hetero_workload(), cfg(StaticInventory()))
        r_frag = simulate(JasdaScheduler(_fragmented()),
                          _hetero_workload(), cfg(FragmentationAware()))
        assert r_frag.n_finished > r_static.n_finished
        coord = r_frag.repartition
        assert coord.n_merges > 0
        # fragmentation was observed high and driven down by the merges
        frags = [f for _, f in coord.frag_trace]
        assert max(frags) > 0.0
        assert frags[-1] < max(frags)
        # merged slices carry canonical interval ids
        assert any(s.startswith("p") for s in r_frag.scheduler.slices)

    def test_window_demand_feeds_frag_aware_ordering(self):
        sched = JasdaScheduler(
            _fragmented(),
            Policy(window=WindowPolicy(kind="frag_aware")))
        coord = RepartitionCoordinator(sched, FragmentationAware())
        for a in _hetero_workload(8):
            sched.add_job(a, 0.0)
        coord.tick(0.0)
        demands = {a.spec.min_capacity for a in sched.agents.values()
                   if a.spec.min_capacity > 0.0}
        assert sched.window_demand is not None
        assert set(sched.window_demand) == demands


# ---------------------------------------------------------------------------
# EnergyAware: consolidate and power-gate
# ---------------------------------------------------------------------------

class TestEnergyAware:
    def test_gates_idle_slices_and_saves_energy(self):
        agents = lambda: make_workload(  # noqa: E731
            6, seed=SEED + 1, arrival_rate=1.0, work_range=(5.0, 15.0),
            mem_range_gb=(1.0, 4.0))
        r_static = simulate(JasdaScheduler(_fragmented()), agents(),
                            SimConfig(t_end=400.0, seed=SEED,
                                      repartition=StaticInventory()))
        r_energy = simulate(JasdaScheduler(_fragmented()), agents(),
                            SimConfig(t_end=400.0, seed=SEED,
                                      repartition=EnergyAware(
                                          gate_after=2, min_active=1)))
        assert r_energy.n_finished == r_energy.n_jobs
        st = r_energy.repartition.stats()
        assert st["n_gates"] > 0
        assert st["n_gated"] >= 1
        # gated chips draw nothing: the proxy strictly undercuts static
        assert (r_energy.repartition.energy_joules
                < r_static.repartition.energy_joules)

    def test_ungate_returns_capacity_under_backlog(self):
        sched = JasdaScheduler(_fragmented()[:2])
        # 1-chip-only lattice: the idle buddies CANNOT consolidate, so the
        # policy falls through to gating
        lat = ProfileLattice((SliceProfile(
            n_chips=1, capacity_bytes=5 * GB, power_watts=350.0,
            idle_watts=52.5),))
        coord = RepartitionCoordinator(
            sched, EnergyAware(gate_after=1, min_active=1,
                               ungate_backlog=10.0), lattice=lat)
        # no work: the first tick past the idle streak gates one slice
        coord.tick(0.0)
        assert len(coord.state.gated) == 1 and len(sched.slices) == 1
        coord.tick(1.0)  # min_active keeps the last slice live
        assert len(sched.slices) == 1
        # heavy backlog: the gated slice comes back via the normal path
        for a in make_workload(12, seed=SEED, work_range=(50.0, 80.0),
                               mem_range_gb=(1.0, 3.0)):
            sched.add_job(a, 2.0)
        coord.tick(2.0)
        assert not coord.state.gated and len(sched.slices) == 2
        assert coord.n_ungates == 1

    def test_energy_model_psi_and_scoring_fold(self):
        em = EnergyModel(watts={"lo": 100.0, "hi": 400.0}, peak=400.0)
        assert em.psi("lo") == pytest.approx(0.75)
        assert em.psi("hi") == 0.0
        assert em.psi("unknown") == 0.0  # unknown slices draw peak
        # an energy beta shifts committed scores toward low-power slices
        # and the run still completes (host-side fold, device untouched)
        scoring = ScoringPolicy(betas={"utilization": 0.2, "slack": 0.1,
                                       "mem_headroom": 0.1, "age": 0.1,
                                       "energy": 0.3})
        for pipeline in (False, True):
            r = simulate(
                JasdaScheduler(_fragmented(), Policy(scoring=scoring)),
                make_workload(6, seed=SEED, work_range=(5.0, 15.0),
                              mem_range_gb=(1.0, 4.0)),
                SimConfig(t_end=300.0, seed=SEED, pipeline=pipeline,
                          repartition=EnergyAware()))
            assert r.n_finished > 0


# ---------------------------------------------------------------------------
# drain-first safety protocol
# ---------------------------------------------------------------------------

class _ForceMergeOnce(RepartitionPolicy):
    """Test policy: propose merging the first sibling pair, once."""

    name = "force-merge"

    def __init__(self):
        self.done = False

    def propose(self, ctx):
        if self.done:
            return []
        pairs = ctx.state.mergeable_pairs(ctx.lattice, live=ctx.specs)
        if not pairs:
            return []
        self.done = True
        return [Move("merge", pairs[0])]


class TestDrainFirst:
    def _busy_sched(self):
        sched = JasdaScheduler(_fragmented()[:2])
        for a in make_workload(6, seed=SEED, work_range=(40.0, 60.0),
                               mem_range_gb=(1.0, 3.0)):
            sched.add_job(a, 0.0)
        for k in range(4):
            sched.run_round(float(k))
        assert sched.commitments  # targets are busy
        return sched

    def test_busy_targets_wait_for_drain(self):
        sched = self._busy_sched()
        coord = RepartitionCoordinator(sched, _ForceMergeOnce(),
                                       drain_grace=100)
        before = _commit_rows(sched)
        coord.tick(4.0)
        # still draining: nothing executed, nothing lost
        assert coord.draining and coord.n_merges == 0
        assert _commit_rows(sched) == before

    def test_grace_exhaustion_revokes_via_slice_failure_path(self):
        sched = self._busy_sched()
        coord = RepartitionCoordinator(sched, _ForceMergeOnce(),
                                       drain_grace=0)
        coord.tick(4.0)
        assert coord.n_merges == 1 and coord.n_forced > 0
        # the revocation wrote ``lost`` rows through the commit log
        assert any(r.status == "lost" for r in sched.commit_log)
        # the merged parent is live under its canonical id
        assert any(s.startswith("p") for s in sched.slices)

    def test_moves_bump_epoch(self):
        sched = JasdaScheduler(_fragmented()[:2])
        coord = RepartitionCoordinator(sched, _ForceMergeOnce())
        e0 = sched._epoch
        coord.tick(0.0)
        assert coord.n_merges == 1
        assert sched._epoch > e0


# ---------------------------------------------------------------------------
# durability: crash resume across a repartition boundary; pipelined identity
# ---------------------------------------------------------------------------

class TestDurability:
    @pytest.mark.parametrize("pipeline", [False, True])
    def test_crash_resume_across_repartition_boundary(self, pipeline, tmp_path):
        def run(tag, faults):
            cfg = SimConfig(t_end=300.0, seed=SEED, pipeline=pipeline,
                            repartition=FragmentationAware())
            store = CheckpointStore(str(tmp_path / f"{tag}_{pipeline}"))
            return simulate(JasdaScheduler(_fragmented()), _hetero_workload(),
                            cfg, faults=faults, checkpoint=store,
                            checkpoint_every=5)

        ref = run("ref", None)
        # the first merges land in the opening ticks (stranded work is
        # visible immediately); crash at t=40.5 restores state that
        # includes the repartitioned layout
        assert any(t <= 40.0 for t, f in ref.repartition.frag_trace if f > 0)
        crash = run("crash", FaultPlan(seed=7, events=(
            FaultEvent(t=40.5, kind=SCHEDULER_CRASH),
            FaultEvent(t=120.5, kind=SCHEDULER_CRASH))))
        assert crash.repartition.n_merges == ref.repartition.n_merges
        assert _sim_key(crash) == _sim_key(ref)

    def test_pipelined_identical_to_serial_with_repartition(self):
        runs = {}
        for pipeline in (False, True):
            r = simulate(JasdaScheduler(_fragmented()), _hetero_workload(),
                         SimConfig(t_end=300.0, seed=SEED, pipeline=pipeline,
                                   repartition=FragmentationAware()))
            assert r.repartition.n_merges > 0  # slice count changed mid-stream
            runs[pipeline] = _sim_key(r)
        assert runs[False] == runs[True]

    def test_coordinator_pickles_with_scheduler(self):
        sched = JasdaScheduler(_fragmented())
        coord = RepartitionCoordinator(sched, FragmentationAware())
        for a in _hetero_workload(8):
            sched.add_job(a, 0.0)
        for k in range(6):
            coord.tick(float(k))
            sched.run_round(float(k))
        sched2, coord2 = pickle.loads(pickle.dumps((sched, coord)))
        assert coord2.scheduler is sched2  # one graph, identity preserved
        assert coord2.state.intervals == coord.state.intervals
        assert coord2.stats() == coord.stats()


# ---------------------------------------------------------------------------
# heterogeneous min_capacity workloads
# ---------------------------------------------------------------------------

class TestWorkloadMinCapacity:
    def test_default_draws_nothing(self):
        a0 = make_workload(10, seed=SEED)
        a1 = make_workload(10, seed=SEED, min_capacity_fraction=0.0)
        assert all(a.spec.min_capacity == 0.0 for a in a1)
        assert ([a.spec.total_work for a in a0]
                == [a.spec.total_work for a in a1])

    def test_fraction_draws_floors_in_range(self):
        agents = make_workload(40, seed=SEED, min_capacity_fraction=0.5,
                               min_capacity_range_gb=(8.0, 20.0))
        floors = [a.spec.min_capacity for a in agents if a.spec.min_capacity]
        assert floors and len(floors) < 40
        assert all(8.0 * GB <= f <= 20.0 * GB for f in floors)
        again = make_workload(40, seed=SEED, min_capacity_fraction=0.5,
                              min_capacity_range_gb=(8.0, 20.0))
        assert [a.spec.min_capacity for a in agents] \
            == [a.spec.min_capacity for a in again]
