"""Policy-driven clearing API: backend equivalence/dominance properties,
unified Policy presets, legacy SchedulerConfig deprecation shim, per-agent θ
threading, and the shared epsilon constants.

The GreedyWIS byte-identity property is pinned against a FROZEN copy of the
PR-2 ``settle_round`` algorithm kept in this file: the production code moved
into ``repro.core.policy``, so only a literal reference copy can detect a
semantic drift of the default backend.  Property tests run under hypothesis
when available and fall back to seeded random pools otherwise (hypothesis is
not in the baked-in environment).
"""
import warnings

import numpy as np
import pytest

from repro.core import (AgentConfig, JasdaScheduler, JobAgent, JobSpec,
                        ScoringPolicy, SimConfig, SliceSpec, make_workload,
                        simulate)
from repro.core.clearing import _fits, _overlap, clear_round, settle_round
from repro.core.fairness import AgePolicy
from repro.core.policy import (ClearingPolicy, FairShare, GlobalAssignment,
                               GreedyWIS, Policy, fixed_point_settle)
from repro.core.scheduler import SchedulerConfig
from repro.core.scoring import score_round
from repro.core.trp import fmp_standard
from repro.core.types import (DEAD_WINDOW_EPS, TIME_EPS, RoundResult, Variant,
                              Window)
from repro.core.windows import DeadWindowRegistry, WindowPolicy
from repro.core.wis import wis_select

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAS_HYPOTHESIS = False

GB = 1 << 30


def _variant(job, sid, t0, dur, h, *, work=None, vid=None, theta=1.0):
    return Variant(
        job_id=job, slice_id=sid, t_start=t0, duration=dur,
        fmp=fmp_standard(1 * GB, 2 * GB, 0.1 * GB),
        local_utility=h, declared_features={},
        payload={"work": work if work is not None else dur},
        variant_id=vid or f"{job}/{sid}/{t0}", theta=theta)


def _random_round(rng, *, n_windows=4, m=60, n_jobs=6, overlap_slices=True):
    """Random multi-window round with plenty of cross-window conflicts."""
    windows = [
        Window(f"s{k}", (4 + 2 * k) * GB,
               0.0 if overlap_slices else 120.0 * k, 100.0)
        for k in range(n_windows)
    ]
    pool = []
    for i in range(m):
        w = windows[int(rng.integers(0, n_windows))]
        t0 = w.t_min + float(rng.uniform(0, w.duration * 0.7))
        dur = float(rng.uniform(2.0, w.t_min + w.duration - t0))
        pool.append(_variant(f"J{i % n_jobs}", w.slice_id, t0, dur,
                             float(rng.uniform(0.1, 0.9)), vid=f"v{i}"))
    budget = {f"J{j}": float(rng.uniform(60.0, 200.0)) for j in range(n_jobs)}
    return windows, pool, budget


def _sig(rr: RoundResult):
    """Byte-comparable signature of a round outcome."""
    return (
        [tuple(v.variant_id for v in r.selected) for r in rr.results],
        [tuple(r.scores) for r in rr.results],
        rr.n_conflicts,
        round(rr.total_score, 12),
    )


# ---------------------------------------------------------------------------
# frozen PR-2 reference: the greedy settle algorithm as shipped before the
# policy API (verbatim semantics; do NOT refactor alongside production code)
# ---------------------------------------------------------------------------

def _reference_settle_pr2(windows, fit, win_idx, scores, *, work_budget=None):
    from repro.core.types import ClearingResult, PoolView

    windows = list(windows)
    view = PoolView.build(fit)
    members = [[] for _ in windows]
    for i, k in enumerate(win_idx):
        members[k].append(i)
    banned = np.zeros(len(fit), dtype=bool)
    selected_per_window = [[] for _ in windows]
    dirty = list(range(len(windows)))
    n_conflicts = 0

    def _reclear(k):
        idx = [i for i in members[k] if not banned[i]]
        if not idx:
            selected_per_window[k] = []
            return
        ia = np.asarray(idx, np.intp)
        sel, _ = wis_select(view.t_start[ia], view.t_end[ia], scores[ia])
        selected_per_window[k] = [idx[int(j)] for j in np.asarray(sel)]

    def _olap(a, b):
        return (a.t_start < b.t_end - 1e-12 and b.t_start < a.t_end - 1e-12)

    while True:
        for k in dirty:
            _reclear(k)
        dirty = []
        wins_by_job = {}
        for k, sel in enumerate(selected_per_window):
            for i in sel:
                wins_by_job.setdefault(fit[i].job_id, []).append(i)
        newly_banned = False
        for job_id, wins in wins_by_job.items():
            if len(wins) < 2 and work_budget is None:
                continue
            wins.sort(key=lambda i: (-scores[i], fit[i].t_start, win_idx[i]))
            kept, used_work = [], 0.0
            budget = work_budget.get(job_id) if work_budget is not None else None
            for i in wins:
                drop = any(_olap(fit[i], fit[j]) and win_idx[i] != win_idx[j]
                           for j in kept)
                if not drop and budget is not None:
                    work = float(fit[i].payload["work"]) if fit[i].payload else 0.0
                    if used_work + work > budget + 1e-9:
                        drop = True
                    else:
                        used_work += work
                if drop:
                    banned[i] = True
                    newly_banned = True
                    n_conflicts += 1
                    if win_idx[i] not in dirty:
                        dirty.append(win_idx[i])
                else:
                    kept.append(i)
        if not newly_banned:
            break

    results, all_selected, all_scores = [], [], []
    for k, w in enumerate(windows):
        sel = sorted(selected_per_window[k], key=lambda i: fit[i].t_start)
        sel_set = set(sel)
        results.append(ClearingResult(
            window=w,
            selected=tuple(fit[i] for i in sel),
            scores=tuple(float(scores[i]) for i in sel),
            total_score=float(sum(scores[i] for i in sel)),
            n_bids=len(members[k]),
            rejected=tuple(fit[i] for i in members[k] if i not in sel_set),
        ))
        all_selected.extend(fit[i] for i in sel)
        all_scores.extend(float(scores[i]) for i in sel)
    return RoundResult(
        windows=tuple(windows), results=tuple(results),
        selected=tuple(all_selected), scores=tuple(all_scores),
        total_score=float(sum(all_scores)), n_bids=len(fit),
        n_conflicts=n_conflicts)


# ---------------------------------------------------------------------------
# GreedyWIS == frozen PR-2 reference (byte-identical), GA >= greedy
# ---------------------------------------------------------------------------

def _check_greedy_matches_reference(seed, *, with_budget):
    rng = np.random.default_rng(seed)
    windows, pool, budget = _random_round(rng)
    budget = budget if with_budget else None
    policy = ScoringPolicy()
    ages = {f"J{j}": 0.15 * j for j in range(6)}
    from repro.core.clearing import assign_bids

    fit, win_idx, view = assign_bids(windows, pool)
    scores = score_round(fit, windows, win_idx, policy, ages=ages, view=view)

    got = GreedyWIS().settle(windows, fit, win_idx, scores,
                             work_budget=budget, view=view)
    ref = _reference_settle_pr2(windows, fit, win_idx, scores,
                                work_budget=budget)
    assert _sig(got) == _sig(ref), "GreedyWIS drifted from PR-2 semantics"
    # settle_round (the free function) must dispatch to the same default
    via_free = settle_round(windows, fit, win_idx, scores,
                            work_budget=budget, view=view)
    assert _sig(via_free) == _sig(ref)


def _check_global_assignment_dominates(seed, *, with_budget):
    rng = np.random.default_rng(seed)
    windows, pool, budget = _random_round(rng)
    budget = budget if with_budget else None
    policy = ScoringPolicy()
    greedy = clear_round(windows, pool, policy, work_budget=budget,
                         clearing=GreedyWIS())
    ga = clear_round(windows, pool, policy, work_budget=budget,
                     clearing=GlobalAssignment())
    assert ga.total_score >= greedy.total_score - 1e-9, \
        "GlobalAssignment cleared less total score than greedy"
    _assert_round_invariants(ga, budget)


def _assert_round_invariants(rr: RoundResult, budget):
    per_job, per_window = {}, {}
    for v in rr.selected:
        per_job.setdefault(v.job_id, []).append(v)
        per_window.setdefault(v.slice_id, []).append(v)
    for vs in per_job.values():
        vs.sort(key=lambda v: v.t_start)
        for a, b in zip(vs, vs[1:]):
            assert b.t_start >= a.t_end - 1e-9, "cross-window double booking"
    for vs in per_window.values():
        vs.sort(key=lambda v: v.t_start)
        for a, b in zip(vs, vs[1:]):
            assert b.t_start >= a.t_end - 1e-9
    if budget:
        for j, vs in per_job.items():
            assert sum(v.payload["work"] for v in vs) <= budget[j] + 1e-6


@pytest.mark.parametrize("with_budget", [False, True])
@pytest.mark.parametrize("seed", range(6))
def test_greedy_wis_byte_identical_to_pr2_reference(seed, with_budget):
    _check_greedy_matches_reference(seed, with_budget=with_budget)


@pytest.mark.parametrize("with_budget", [False, True])
@pytest.mark.parametrize("seed", range(6))
def test_global_assignment_never_below_greedy(seed, with_budget):
    _check_global_assignment_dominates(seed, with_budget=with_budget)


if HAS_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), with_budget=st.booleans())
    def test_greedy_identity_property(seed, with_budget):
        _check_greedy_matches_reference(seed, with_budget=with_budget)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), with_budget=st.booleans())
    def test_global_assignment_dominance_property(seed, with_budget):
        _check_global_assignment_dominates(seed, with_budget=with_budget)


def test_global_assignment_strictly_recovers_dropped_utility():
    # J0 wins both windows with overlapping intervals; greedy keeps its best
    # (0.9 on sA) and leaves sB EMPTY after the re-clear, also displacing
    # J1's 0.85 substitute bid on sA.  The assignment moves J0 to sB so sA
    # re-clears to J1: total 1.65 vs greedy's 0.9.
    wa, wb = Window("sA", 8 * GB, 0.0, 20.0), Window("sB", 8 * GB, 0.0, 20.0)
    pool = [_variant("J0", "sA", 0.0, 10.0, 0.90, vid="j0a"),
            _variant("J0", "sB", 0.0, 10.0, 0.80, vid="j0b"),
            _variant("J1", "sA", 0.0, 10.0, 0.85, vid="j1a")]
    scoring = ScoringPolicy(lam=1.0, alphas={}, betas={})
    greedy = clear_round([wa, wb], pool, scoring, clearing=GreedyWIS())
    ga = clear_round([wa, wb], pool, scoring, clearing=GlobalAssignment())
    assert sorted(v.variant_id for v in greedy.selected) == ["j0a"]
    assert sorted(v.variant_id for v in ga.selected) == ["j0b", "j1a"]
    assert ga.total_score > greedy.total_score + 0.5


# ---------------------------------------------------------------------------
# FairShare: age-boosted selection + win spreading
# ---------------------------------------------------------------------------

def test_fairshare_promotes_starved_job():
    # same window, overlapping bids: J_new scores higher, J_starved has been
    # waiting (age 1.0).  GreedyWIS picks the raw-score winner; FairShare's
    # age boost flips the selection.  Reported scores stay RAW.
    w = Window("s0", 8 * GB, 0.0, 20.0)
    pool = [_variant("J_new", "s0", 0.0, 10.0, 0.80, vid="new"),
            _variant("J_starved", "s0", 0.0, 10.0, 0.70, vid="starved")]
    scoring = ScoringPolicy(lam=1.0, alphas={}, betas={})
    ages = {"J_new": 0.0, "J_starved": 1.0}
    greedy = clear_round([w], pool, scoring, ages=ages, clearing=GreedyWIS())
    fair = clear_round([w], pool, scoring, ages=ages,
                       clearing=FairShare(age_weight=0.5, spread=0.0))
    assert [v.variant_id for v in greedy.selected] == ["new"]
    assert [v.variant_id for v in fair.selected] == ["starved"]
    # raw auction score reported, not the boosted selection score
    assert fair.scores[0] == pytest.approx(0.70, abs=1e-6)


def test_fairshare_spreads_wins_across_jobs():
    # J_rich can fill both windows with slightly better bids; J_poor has one
    # bid per window.  With spreading, J_rich's second seat yields to J_poor.
    wa, wb = Window("sA", 8 * GB, 0.0, 20.0), Window("sB", 8 * GB, 30.0, 20.0)
    pool = [_variant("J_rich", "sA", 0.0, 10.0, 0.80, vid="ra"),
            _variant("J_rich", "sB", 30.0, 10.0, 0.78, vid="rb"),
            _variant("J_poor", "sA", 0.0, 10.0, 0.75, vid="pa"),
            _variant("J_poor", "sB", 30.0, 10.0, 0.74, vid="pb")]
    scoring = ScoringPolicy(lam=1.0, alphas={}, betas={})
    greedy = clear_round([wa, wb], pool, scoring, clearing=GreedyWIS())
    fair = clear_round([wa, wb], pool, scoring,
                       clearing=FairShare(age_weight=0.0, spread=0.5))
    assert sorted(v.variant_id for v in greedy.selected) == ["ra", "rb"]
    jobs_fair = sorted(v.job_id for v in fair.selected)
    assert jobs_fair == ["J_poor", "J_rich"], \
        "win spreading should give each job one window"


# ---------------------------------------------------------------------------
# unified Policy object + presets + deprecation shim
# ---------------------------------------------------------------------------

def test_policy_presets_compose_and_validate():
    util, fair, resp = Policy.utilization(), Policy.fairness(), Policy.responsive()
    assert isinstance(util.clearing, GlobalAssignment)
    assert isinstance(fair.clearing, FairShare)
    assert isinstance(resp.clearing, GreedyWIS)
    assert util.scoring.lam == 0.3 and resp.scoring.lam == 0.7
    assert util.window.kind == "best_fit"
    assert fair.scoring.beta_age == 0.5 and fair.age.tau == 30.0
    # presets accept overrides and stay frozen value objects
    p = Policy.responsive(per_agent_theta=True)
    assert p.per_agent_theta and p.name == "responsive"
    assert Policy() == Policy() and Policy() != util
    for preset in (util, fair, resp):
        assert preset.describe()
    with pytest.raises(ValueError):
        Policy(recheck_theta=0.0)
    with pytest.raises(ValueError):
        Policy(recheck_theta=1.5)
    with pytest.raises(TypeError):
        Policy(clearing="greedy")
    with pytest.raises(TypeError):
        Policy(scoring={"lam": 0.5})


def test_legacy_scheduler_config_deprecated_but_working():
    slices = [SliceSpec("s0", 20 * GB, n_chips=4)]
    legacy_cfg = SchedulerConfig(scoring=ScoringPolicy(lam=0.3),
                                 window=WindowPolicy(kind="largest"))
    with pytest.warns(DeprecationWarning, match="Policy"):
        sched = JasdaScheduler(slices, legacy_cfg)
    # fragments survive the conversion and the scheduler still schedules
    assert sched.policy.scoring.lam == 0.3
    assert sched.policy.window.kind == "largest"
    assert isinstance(sched.policy.clearing, GreedyWIS)
    for a in make_workload(5, seed=3, arrival_rate=5.0):
        sched.add_job(a, 0.0)
    assert sched.run_round(2.0) is not None

    # runtime-knob-only configs are NOT deprecated
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        JasdaScheduler(slices, SchedulerConfig(score_impl="ref", max_log_rows=10))
        JasdaScheduler(slices)
        # ...and neither is the blessed Policy + runtime-knobs combination,
        # including after dataclasses.replace of a from_policy config
        import dataclasses

        cfg = SchedulerConfig.from_policy(Policy.utilization(), score_impl="ref")
        sched = JasdaScheduler(slices, cfg)
        replaced = dataclasses.replace(cfg, max_log_rows=10)
        sched2 = JasdaScheduler(slices, replaced)
    # the original Policy (preset name, backend) survives both round-trips
    assert sched.policy == Policy.utilization()
    assert sched.config.score_impl == "ref"
    assert sched2.policy == Policy.utilization()
    assert sched2.config.max_log_rows == 10


def test_legacy_config_equals_policy_constructed_scheduler():
    slices = lambda: [SliceSpec("s0", 20 * GB, n_chips=4),
                      SliceSpec("s1", 10 * GB, n_chips=2)]
    with pytest.warns(DeprecationWarning):
        legacy = JasdaScheduler(slices(), SchedulerConfig(
            scoring=ScoringPolicy(lam=0.7)))
    unified = JasdaScheduler(slices(), Policy(scoring=ScoringPolicy(lam=0.7)))
    r1 = simulate(legacy, make_workload(10, seed=5, arrival_rate=0.8),
                  SimConfig(t_end=400.0, seed=2))
    r2 = simulate(unified, make_workload(10, seed=5, arrival_rate=0.8),
                  SimConfig(t_end=400.0, seed=2))
    assert [(c.variant_id, c.t_start) for c in legacy.commit_log] == \
        [(c.variant_id, c.t_start) for c in unified.commit_log]
    assert r1.total_score == pytest.approx(r2.total_score, abs=1e-9)
    assert r2.clearing == "greedy_wis"


@pytest.mark.parametrize("preset", ["utilization", "fairness", "responsive"])
def test_presets_run_end_to_end(preset):
    policy = getattr(Policy, preset)()
    sched = JasdaScheduler([SliceSpec("s20", 20 * GB, n_chips=4),
                            SliceSpec("s10", 10 * GB, n_chips=2)], policy)
    res = simulate(sched, make_workload(12, seed=7, arrival_rate=0.5),
                   SimConfig(t_end=800.0, seed=3))
    assert res.n_finished == 12
    assert res.policy == preset
    assert res.clearing == policy.clearing.name
    # the audit trail stays double-booking-free under every backend
    per_job = {}
    for r in sched.commit_log:
        if r.status in ("active", "completed"):
            per_job.setdefault(r.job_id, []).append(r.interval)
    for ivs in per_job.values():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert s2 >= e1 - 1e-9


def test_pipelined_rounds_byte_identical_under_policy():
    # acceptance: the default policy is byte-identical under the pipelined
    # and serial paths (the settle backend is pure, so speculation replays)
    def run(pipeline):
        sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4),
                                SliceSpec("s1", 10 * GB, n_chips=2)], Policy())
        simulate(sched, make_workload(10, seed=11, arrival_rate=0.8),
                 SimConfig(t_end=400.0, seed=4, pipeline=pipeline))
        return [(c.variant_id, c.t_start, c.score) for c in sched.commit_log]

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# per-agent θ threading (satellite)
# ---------------------------------------------------------------------------

def test_variant_theta_flows_from_agent_config():
    spec = JobSpec(job_id="J0", arrival_time=0.0, total_work=50.0,
                   fmp=fmp_standard(1 * GB, 2 * GB, 0.1 * GB))
    agent = JobAgent(spec, AgentConfig(theta=0.17))
    w = Window("s0", 8 * GB, 0.0, 30.0)
    variants = agent.generate_variants_round([w], 0.0)
    assert variants and all(v.theta == 0.17 for v in variants)


def test_packed_round_thetas_are_per_agent():
    from repro.core.clearing import assign_bids
    from repro.kernels.jasda_score.ops import pool_to_arrays_round

    w = Window("s0", 8 * GB, 0.0, 30.0)
    pool = [_variant("J0", "s0", 0.0, 10.0, 0.5, vid="a", theta=0.02),
            _variant("J1", "s0", 10.0, 10.0, 0.5, vid="b", theta=0.4)]
    fit, win_idx, view = assign_bids([w], pool)
    packed = pool_to_arrays_round(
        fit, [w], win_idx, ScoringPolicy(), h=view.local_utility,
        pack_grids=True, theta=view.thetas, view=view)
    np.testing.assert_allclose(packed.thetas, [0.02, 0.4])


def test_per_agent_theta_recheck_discriminates():
    # identical bids except θ: the FMP sits close enough to capacity that
    # p_exceed falls between the strict and the loose agent bound, so the
    # in-dispatch recheck zeroes exactly the strict agent's bid
    from repro.core.trp import prob_exceed_grid

    cap = 3.1 * GB
    fmp = fmp_standard(1 * GB, 3 * GB, 0.05 * GB, rel_sigma=0.01)
    mu, sigma = fmp.grid(32)
    p = prob_exceed_grid(mu, sigma, cap)  # ≈ 0.11 for this FMP/capacity
    assert 1e-6 < p < 0.5, f"test FMP mis-calibrated: p_exceed={p}"
    w = Window("s0", cap, 0.0, 30.0)
    strict = Variant(job_id="JS", slice_id="s0", t_start=0.0, duration=10.0,
                     fmp=fmp, local_utility=0.8, declared_features={},
                     payload={"work": 10.0}, variant_id="strict", theta=p / 10)
    loose = Variant(job_id="JL", slice_id="s0", t_start=10.0, duration=10.0,
                    fmp=fmp, local_utility=0.8, declared_features={},
                    payload={"work": 10.0}, variant_id="loose", theta=min(1.0, p * 10))
    scores = score_round([strict, loose], [w], [0, 0], ScoringPolicy(),
                         per_agent_theta=True, impl="numpy")
    assert scores[0] == 0.0, "strict-θ bid must fail its own recheck"
    assert scores[1] > 0.0, "loose-θ bid must pass its own recheck"
    # scheduler-wide override takes precedence over per-agent θ
    override = score_round([strict, loose], [w], [0, 0], ScoringPolicy(),
                           per_agent_theta=True, recheck_theta=min(1.0, p * 10),
                           impl="numpy")
    assert override[0] > 0.0 and override[1] > 0.0


def test_scheduler_per_agent_theta_end_to_end():
    # a policy with per_agent_theta wires Variant.theta into the dispatch;
    # with the workload's generation-time safety already enforced, the
    # recheck must not zero any honest bid (selections still commit)
    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)],
                           Policy(per_agent_theta=True))
    for a in make_workload(5, seed=3, arrival_rate=5.0):
        sched.add_job(a, 0.0)
    rr = sched.run_round(2.0)
    assert rr is not None and rr.selected


# ---------------------------------------------------------------------------
# shared epsilon constants (satellite)
# ---------------------------------------------------------------------------

def test_epsilon_constants_are_shared():
    import inspect

    from repro.core.types import OVERLAP_EPS, overlaps

    # one base constant, three derived tolerances with fixed relationships
    assert OVERLAP_EPS == 1e-3 * TIME_EPS
    assert DEAD_WINDOW_EPS == 1e3 * TIME_EPS
    assert OVERLAP_EPS < TIME_EPS < DEAD_WINDOW_EPS
    assert DeadWindowRegistry().eps == DEAD_WINDOW_EPS
    assert SchedulerConfig().dead_window_eps == DEAD_WINDOW_EPS
    # the clearing predicates take their defaults from the shared constants
    assert inspect.signature(_fits).parameters["eps"].default is TIME_EPS
    assert inspect.signature(_overlap).parameters["eps"].default is OVERLAP_EPS
    assert inspect.signature(overlaps).parameters["eps"].default is OVERLAP_EPS
    # semantics at the boundary: touching intervals are compatible,
    # sub-epsilon drift does not flip fit/overlap verdicts
    a = _variant("J0", "s0", 0.0, 10.0, 0.5)
    b = _variant("J1", "s0", 10.0, 5.0, 0.5)
    assert not _overlap(a, b)
    c = _variant("J2", "s0", 10.0 - OVERLAP_EPS / 2, 5.0, 0.5)
    assert not _overlap(a, c), "sub-epsilon overlap must be tolerated"
    w = Window("s0", 8 * GB, 0.0, 10.0)
    d = _variant("J3", "s0", 0.0, 10.0 + TIME_EPS / 2, 0.5)
    assert _fits(d, w), "sub-epsilon boundary excess must still fit"


# ---------------------------------------------------------------------------
# custom backends plug in through the same protocol
# ---------------------------------------------------------------------------

def test_custom_clearing_policy_dispatches():
    from dataclasses import dataclass

    @dataclass(frozen=True)
    class FirstWindowOnly(ClearingPolicy):
        """Degenerate backend: clears only the first announced window."""

        name = "first_window_only"

        def settle(self, windows, fit, win_idx, scores, *, selector=wis_select,
                   work_budget=None, view=None, ages=None):
            keep = [i for i, k in enumerate(win_idx) if k == 0]
            sub_idx = [0] * len(keep)
            sub_fit = [fit[i] for i in keep]
            rr = fixed_point_settle([windows[0]], sub_fit, sub_idx,
                                    np.asarray(scores)[keep],
                                    selector=selector, work_budget=work_budget)
            from repro.core.types import ClearingResult

            results = list(rr.results) + [
                ClearingResult(window=w, selected=(), scores=(),
                               total_score=0.0, n_bids=0)
                for w in windows[1:]
            ]
            return RoundResult(tuple(windows), tuple(results), rr.selected,
                               rr.scores, rr.total_score, len(fit),
                               n_conflicts=rr.n_conflicts)

    rng = np.random.default_rng(0)
    windows, pool, _ = _random_round(rng, overlap_slices=False)
    rr = clear_round(windows, pool, ScoringPolicy(),
                     clearing=FirstWindowOnly())
    assert rr.results[0].selected
    assert all(not r.selected for r in rr.results[1:])
    # and through the scheduler path via Policy
    sched = JasdaScheduler([SliceSpec("s0", 20 * GB, n_chips=4)],
                           Policy(name="custom", clearing=FirstWindowOnly()))
    assert isinstance(sched.policy.clearing, FirstWindowOnly)
