"""Sharding rules: logical resolution, divisibility guard, spec kinds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro.distributed.sharding import ShardingRules, resolve_param_specs
from repro.models import Model
from repro.configs import ARCH_NAMES, get


@pytest.fixture(scope="module")
def mesh():
    # spec-resolution tests never execute on the mesh, so an abstract
    # (deviceless) mesh of the production shape is exact and portable
    return jax.sharding.AbstractMesh((2, 2), ("data", "model"))


def test_resolve_logical_axes(mesh):
    rules = ShardingRules(mesh=mesh, fsdp_axes=("data",))
    assert rules.resolve(("fsdp", "model")) == PS(("data",), ("model",))
    assert rules.resolve((None, "model")) == PS(None, ("model",))
    with pytest.raises(ValueError):
        rules.resolve(("bogus",))


def test_activation_kinds(mesh):
    rules = ShardingRules(mesh=mesh, batch_axes=("data",))
    for kind in ("btd", "btf", "btm", "bshk", "btkk", "btv", "gecd", "gecf"):
        spec = rules.spec(kind)
        assert isinstance(spec, PS)


def test_divisibility_guard_drops_invalid(mesh):
    from repro.distributed.sharding import guard_spec
    rules = ShardingRules(mesh=mesh, batch_axes=("data",))
    # dim 3 not divisible by data=2 → entry dropped; dims 4/8 fine
    spec = guard_spec(rules.spec("btd"), (3, 4, 8), {"data": 2, "model": 2})
    assert spec == PS(None, None, None)
    spec2 = guard_spec(rules.spec("btd"), (4, 4, 8), {"data": 2, "model": 2})
    assert spec2 == PS(("data",), None, None)


def test_headdim_mode_kv_spec(mesh):
    rules = ShardingRules(mesh=mesh, attn_shard="headdim",
                          batch_axes=("data",))
    assert rules.spec("btkk") == PS(("data",), None, None, ("model",))
    rules2 = ShardingRules(mesh=mesh, shard_kv_seq=True, batch_axes=("data",))
    assert rules2.spec("btkk") == PS(("data",), ("model",), None, None)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_resolve_for_all_archs(arch, mesh):
    """Every arch's logical spec tree resolves; model-sharded dims divide 16
    (the production model-axis), guaranteed by config padding choices."""
    cfg, info = get(arch)
    model = Model(cfg)
    logical = model.specs()
    rules = ShardingRules(mesh=mesh, fsdp_axes=("data",))
    resolved = resolve_param_specs(logical, rules)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def check(path, spec, sds):
        for dim, entry in zip(sds.shape, tuple(spec)):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            if "model" in axes:
                assert dim % cfg.model_axis_size == 0, (
                    f"{arch} {jax.tree_util.keystr(path)}: dim {dim} "
                    f"not divisible by model axis {cfg.model_axis_size}")

    jax.tree_util.tree_map_with_path(
        check, resolved, shapes,
        is_leaf=lambda x: isinstance(x, PS))
