"""TRP/FMP: safety evaluators vs Monte-Carlo ground truth (paper §4.1a)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.trp import (PhaseFMP, Phase, fmp_from_model, fmp_standard,
                            fmp_static, is_safe, predict_duration,
                            prob_exceed_grid, prob_exceed_union)


def test_grid_prob_matches_monte_carlo():
    fmp = fmp_standard(4e9, 10e9, 2e9, rel_sigma=0.05)
    mu, sigma = fmp.grid(64)
    cap = 12.5e9
    p_grid = prob_exceed_grid(mu, sigma, cap)
    rng = np.random.default_rng(0)
    n = 40000
    hits = 0
    for _ in range(n):
        traj = rng.normal(mu, sigma)
        hits += np.any(traj > cap)
    p_mc = hits / n
    assert p_grid == pytest.approx(p_mc, abs=0.01)


def test_union_bound_dominates_grid():
    fmp = fmp_standard(4e9, 10e9, 1e9, rel_sigma=0.1)
    mu, sigma = fmp.grid(64)
    for cap in (10.5e9, 11.5e9, 13e9):
        assert prob_exceed_union(mu, sigma, cap) >= prob_exceed_grid(mu, sigma, cap) - 1e-12


def test_deterministic_violation_certain():
    fmp = fmp_static(10e9, 0.0)
    mu, sigma = fmp.grid(8)
    assert prob_exceed_grid(mu, sigma, 9e9) == 1.0
    assert prob_exceed_grid(mu, sigma, 11e9) == 0.0


@settings(max_examples=40, deadline=None)
@given(st.floats(1e8, 1e10), st.floats(0.0, 0.2))
def test_safety_monotone_in_capacity(steady, rel_sigma):
    fmp = fmp_standard(steady * 0.3, steady, steady * 0.1,
                       rel_sigma=max(rel_sigma, 1e-4))
    mu, sigma = fmp.grid(32)
    caps = np.linspace(steady * 0.5, steady * 2.0, 8)
    ps = [prob_exceed_grid(mu, sigma, c) for c in caps]
    assert all(a >= b - 1e-12 for a, b in zip(ps, ps[1:]))


def test_is_safe_theta_boundary():
    fmp = fmp_standard(1e9, 2e9, 0.0, rel_sigma=0.02)
    assert is_safe(fmp, 3e9, theta=0.05)
    assert not is_safe(fmp, 1.9e9, theta=0.05)


def test_phase_fractions_validated():
    with pytest.raises(ValueError):
        PhaseFMP((Phase(0.5, 1, 1, 0),))


def test_predict_duration_quantile():
    # declared duration at q=0.9 exceeds the median but not wildly
    med = 100 / 4.0
    d = predict_duration(100, 4.0, cv=0.1, quantile=0.9)
    assert med < d < med * 1.25
    # q=0.5 returns the median
    assert predict_duration(100, 4.0, cv=0.1, quantile=0.5) == pytest.approx(med)


def test_fmp_from_model_shape():
    fmp = fmp_from_model(param_bytes=1e9, optimizer_bytes=2e9,
                         activation_bytes=5e8, kv_cache_bytes=1e8)
    assert fmp.peak_mean() > 3.1e9  # base + activations + burst
    mu, sigma = fmp.grid(16)
    assert mu.shape == (16,) and np.all(sigma >= 0)
