"""Model zoo behaviour: fwd/bwd, prefill+decode ≡ forward, MoE/scan paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig


def tiny(family, **kw):
    base = dict(name=f"tiny-{family}", family=family, n_layers=4, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                model_axis_size=2, dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


CFGS = {
    "dense": tiny("dense", qk_norm=True, qkv_bias=True),
    "moe": tiny("moe", n_experts=8, top_k=2, d_expert=64, capacity_factor=8.0),
    "ssm": tiny("ssm", n_heads=1, n_kv_heads=1, d_ff=0, ssm_state=8),
    "hybrid": tiny("hybrid", n_layers=8, pattern=("rglru", "rglru", "attn"),
                   window=16, n_kv_heads=1),
    "encdec": tiny("encdec", n_encoder_layers=2, encoder_seq=32,
                   max_pos_embed=128, gated_mlp=False, act="gelu"),
    "vlm": tiny("vlm", n_layers=10, cross_attn_every=5, vision_seq=16),
}


def _batch(cfg, key, B=2, S=24):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["memory"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["memory"] = jax.random.normal(key, (B, cfg.vision_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("family", list(CFGS))
def test_forward_backward_finite(family):
    cfg = CFGS[family]
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(lambda p: m.loss_fn(p, batch))(params)
    assert jnp.isfinite(loss)
    for g in jax.tree.leaves(grads):
        assert jnp.all(jnp.isfinite(g))


@pytest.mark.parametrize("family", list(CFGS))
def test_prefill_decode_matches_forward(family):
    cfg = CFGS[family]
    m = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    B, S = 2, 24
    batch = _batch(cfg, key, B, S)
    tokens, memory = batch["tokens"], batch.get("memory")
    logits_full, _ = m.forward(params, tokens, memory=memory, remat=False)
    _, cache, cross = m.prefill(params, tokens[:, :S - 1], memory=memory,
                                max_seq=S)
    logits_dec, _ = m.decode_step(params, tokens[:, S - 1], jnp.int32(S - 1),
                                  cache, cross_stack=cross)
    np.testing.assert_allclose(np.asarray(logits_full[:, S - 1]),
                               np.asarray(logits_dec), atol=3e-4)


def test_multistep_decode_consistency():
    cfg = CFGS["dense"]
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    B, S = 2, 20
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    logits_full, _ = m.forward(params, tokens, remat=False)
    _, cache, _ = m.prefill(params, tokens[:, :10], max_seq=S)
    for t in range(10, S):
        logits_dec, cache = m.decode_step(params, tokens[:, t], jnp.int32(t), cache)
        np.testing.assert_allclose(np.asarray(logits_full[:, t]),
                                   np.asarray(logits_dec), atol=3e-4)


def test_hybrid_ring_cache_beyond_window():
    """Decode past the window: ring overwrite must preserve exactness."""
    cfg = CFGS["hybrid"]  # window 16
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(4))
    B, S = 1, 40  # well past the window
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    logits_full, _ = m.forward(params, tokens, remat=False)
    _, cache, _ = m.prefill(params, tokens[:, :24], max_seq=S)
    for t in range(24, S):
        logits_dec, cache = m.decode_step(params, tokens[:, t], jnp.int32(t), cache)
        np.testing.assert_allclose(np.asarray(logits_full[:, t]),
                                   np.asarray(logits_dec), atol=3e-4,
                                   err_msg=f"divergence at position {t}")


def test_attention_impls_agree():
    from repro.models.layers import attention
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    B, S, H, hd = 2, 64, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, 2, hd))
    v = jax.random.normal(ks[2], (B, S, 2, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    outs = {}
    for impl in ("full", "chunked", "triangle", "pallas"):
        outs[impl] = attention(q, k, v, q_positions=pos, k_positions=pos,
                               causal=True, impl=impl, chunk_q=16)
    for impl in ("chunked", "triangle", "pallas"):
        np.testing.assert_allclose(np.asarray(outs[impl]),
                                   np.asarray(outs["full"]), atol=2e-5,
                                   err_msg=impl)


def test_moe_capacity_drops_tokens():
    """With a tight capacity factor some tokens must be dropped (residual
    passthrough) — the aux loss keeps the router balanced over training."""
    cfg = tiny("moe", n_experts=4, top_k=1, d_expert=32, capacity_factor=0.5)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(7))
    batch = _batch(cfg, jax.random.PRNGKey(8))
    loss = m.loss_fn(params, batch)
    assert jnp.isfinite(loss)


def test_remat_matches_no_remat():
    cfg = CFGS["dense"]
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(9))
    batch = _batch(cfg, jax.random.PRNGKey(10))
    l1 = m.loss_fn(params, batch, remat=True)
    l2 = m.loss_fn(params, batch, remat=False)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)
