"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement).  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get, reduced
from repro.models import Model
from repro.training import adamw, constant, make_train_step


def _batch(cfg, key, B=2, S=16):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["memory"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["memory"] = jax.random.normal(
            key, (B, cfg.vision_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_config_train_step(arch):
    cfg = reduced(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)

    # forward: shape + finiteness
    logits, _ = model.forward(params, batch["tokens"],
                              memory=batch.get("memory"), remat=False)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one full train step
    opt = adamw(constant(1e-3))
    opt_state = opt.init(params)
    step = make_train_step(model, opt, microbatches=1)
    params2, opt_state2, metrics = step(params, opt_state, batch, jnp.int32(0))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_config_decode_step(arch):
    cfg = reduced(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B, S = 2, 12
    batch = _batch(cfg, key, B, S)
    _, cache, cross = model.prefill(
        params, batch["tokens"][:, :S - 1], memory=batch.get("memory"),
        max_seq=S)
    logits, cache2 = model.decode_step(
        params, batch["tokens"][:, S - 1], jnp.int32(S - 1), cache,
        cross_stack=cross)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_assigned_configs_match_assignment():
    """The exact table from the assignment."""
    expect = {
        "whisper_small": dict(n_layers=12, d_model=768, n_heads=12,
                              n_kv_heads=12, d_ff=3072, vocab_size=51865),
        "starcoder2_15b": dict(n_layers=40, d_model=6144, n_heads=48,
                               n_kv_heads=4, d_ff=24576, vocab_size=49152),
        "qwen1_5_4b": dict(n_layers=40, d_model=2560, n_heads=20,
                           n_kv_heads=20, d_ff=6912, vocab_size=151936),
        "qwen3_14b": dict(n_layers=40, d_model=5120, n_heads=40,
                          n_kv_heads=8, d_ff=17408, vocab_size=151936),
        "llama3_405b": dict(n_layers=126, d_model=16384, n_heads=128,
                            n_kv_heads=8, d_ff=53248, vocab_size=128256),
        "falcon_mamba_7b": dict(n_layers=64, d_model=4096, ssm_state=16,
                                vocab_size=65024),
        "olmoe_1b_7b": dict(n_layers=16, d_model=2048, n_heads=16,
                            n_kv_heads=16, d_expert=1024, vocab_size=50304,
                            n_experts=64, top_k=8),
        "granite_moe_3b_a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, d_expert=512,
                                     vocab_size=49155, n_experts=40, top_k=8),
        "recurrentgemma_9b": dict(n_layers=38, d_model=4096, n_heads=16,
                                  n_kv_heads=1, d_ff=12288, vocab_size=256000),
        "llama3_2_vision_90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                    n_kv_heads=8, d_ff=28672,
                                    vocab_size=128256),
    }
    for arch, fields in expect.items():
        cfg, _ = get(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_all_four_shapes_defined():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].seq == 4096 and SHAPES["train_4k"].batch == 256
    assert SHAPES["prefill_32k"].seq == 32768 and SHAPES["prefill_32k"].batch == 32
    assert SHAPES["decode_32k"].seq == 32768 and SHAPES["decode_32k"].batch == 128
    assert SHAPES["long_500k"].seq == 524288 and SHAPES["long_500k"].batch == 1
