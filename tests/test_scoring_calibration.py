"""Scoring model (Eqs. 1–4) + calibration/verification (§4.2.1)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.calibration import (CalibrationConfig, Calibrator,
                                    per_variant_error, reliability)
from repro.core.scoring import (POLICY_BALANCED, ScoringPolicy,
                                composite_score, job_utility, score_pool,
                                system_utility)
from repro.core.trp import fmp_standard
from repro.core.types import Variant, Window


def _variant(job="J1", t0=0.0, dur=5.0, h=0.6, feats=None):
    return Variant(
        job_id=job, slice_id="s0", t_start=t0, duration=dur,
        fmp=fmp_standard(1e9, 2e9, 0.0), local_utility=h,
        declared_features=feats or {"jct": 0.7, "qos": 1.0, "progress": 0.4},
        payload={"work": 1.0})


def _window(cap=8e9, t0=0.0, dur=10.0):
    return Window("s0", cap, t0, dur)


# ---------------------------------------------------------------------------
# normalization bounds (paper: Score(v) ∈ [0,1] by construction)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.floats(0, 1), st.floats(0, 1), st.floats(0, 1))
def test_composite_score_in_unit_interval(lam, h, f):
    assert 0.0 <= composite_score(h, f, lam) <= 1.0


def test_policy_weight_validation():
    with pytest.raises(ValueError):
        ScoringPolicy(lam=1.5)
    with pytest.raises(ValueError):
        ScoringPolicy(alphas={"jct": 0.9, "qos": 0.3})  # Σα > 1
    with pytest.raises(ValueError):
        ScoringPolicy(betas={"utilization": -0.1})


def test_score_pool_bounds_and_order():
    w = _window()
    pol = POLICY_BALANCED
    vs = [_variant(h=0.2), _variant(h=0.9)]
    scores = score_pool(vs, w, pol)
    assert np.all(scores >= 0) and np.all(scores <= 1)
    assert scores[1] > scores[0]  # higher declared utility → higher score


def test_system_utility_features():
    w = _window(dur=10.0)
    v_full = _variant(dur=10.0)  # fills the window
    v_half = _variant(dur=5.0)
    pol = ScoringPolicy(lam=0.0, betas={"utilization": 1.0})
    assert system_utility(v_full, w, pol) > system_utility(v_half, w, pol)


def test_age_term_raises_score():
    w = _window()
    pol = ScoringPolicy(lam=0.5, betas={"utilization": 0.5, "age": 0.5})
    v = _variant()
    s_young = score_pool([v], w, pol, ages={"J1": 0.0})[0]
    s_old = score_pool([v], w, pol, ages={"J1": 1.0})[0]
    assert s_old > s_young


# ---------------------------------------------------------------------------
# §4.2.1: ε, ρ, calibration dynamics
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.dictionaries(st.sampled_from(["a", "b", "c"]),
                       st.floats(0, 1), min_size=1),
       st.dictionaries(st.sampled_from(["a", "b", "c"]),
                       st.floats(0, 1), min_size=1))
def test_per_variant_error_bounded(declared, observed):
    eps = per_variant_error(declared, observed)
    assert 0.0 <= eps <= 1.0


def test_reliability_bounds_and_decay():
    assert reliability(0.0, 3.0) == 1.0
    r = [reliability(e, 3.0) for e in (0.0, 0.1, 0.5, 1.0)]
    assert all(0 < x <= 1 for x in r)
    assert all(a > b for a, b in zip(r, r[1:]))  # monotone decay


def test_calibrator_penalizes_misreporting():
    cal = Calibrator(CalibrationConfig(kappa=3.0))
    honest, liar = _variant(job="H"), _variant(job="L")
    for _ in range(10):
        cal.verify(honest, dict(honest.declared_features))  # exact match
        observed = {k: max(0.0, v - 0.5) for k, v in liar.declared_features.items()}
        cal.verify(liar, observed)  # overstated by 0.5
    assert cal.rho("H") > 0.95
    assert cal.rho("L") < 0.5
    # calibrated score of the liar is pulled toward its history
    h_liar = cal.calibrate(liar, 0.9)
    assert h_liar < 0.9


def test_calibrate_modes():
    for mode in ("fixed", "reliability", "multiplicative"):
        cal = Calibrator(CalibrationConfig(mode=mode))
        v = _variant()
        h = cal.calibrate(v, 0.8)
        assert 0.0 <= h <= 1.0


def test_hist_avg_tracks_observations():
    cal = Calibrator(CalibrationConfig(hist_half_life=2.0))
    v = _variant(job="J")
    for _ in range(20):
        cal.verify(v, {"jct": 0.9, "qos": 0.9, "progress": 0.9},
                   observed_utility=0.9)
    assert cal.hist_avg("J") == pytest.approx(0.9, abs=0.05)
