"""Training substrate: optimizers, schedules, data, checkpoint, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, SyntheticTokens, prefetch
from repro.distributed.compression import (compress, decompress, init_error)
from repro.training import (adafactor, adamw, apply_updates,
                            clip_by_global_norm, constant, global_norm,
                            make_train_step, warmup_cosine)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    opt = adamw(constant(0.1), weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for i in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw (w²)
        updates, state = opt.update(grads, state, params, jnp.int32(i))
        params = apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adafactor_minimizes_quadratic():
    opt = adafactor(constant(0.3))
    params = {"w": jnp.full((4, 4), 3.0)}
    state = opt.init(params)
    for i in range(300):
        grads = {"w": 2 * params["w"]}
        updates, state = opt.update(grads, state, params, jnp.int32(i))
        params = apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adafactor_state_is_factored():
    opt = adafactor(constant(1e-3))
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    state = opt.init(params)
    assert state["stats"]["w"]["vr"].shape == (64,)
    assert state["stats"]["w"]["vc"].shape == (32,)
    assert state["stats"]["b"]["v"].shape == (32,)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((10,), 1e-3)}
    same, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(small["a"]))


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, 100, 1000)
    assert float(lr(jnp.int32(0))) < float(lr(jnp.int32(99)))
    assert float(lr(jnp.int32(100))) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr(jnp.int32(999))) < 2e-4


def test_grad_accumulation_equivalence():
    """microbatches=4 must equal microbatches=1 (same data)."""
    from repro.models import Model, ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                      model_axis_size=1, dtype=jnp.float32)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw(constant(1e-2))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128)
    batch = {"tokens": tokens, "labels": tokens}
    outs = []
    for mb in (1, 4):
        st = opt.init(params)
        step = make_train_step(m, opt, microbatches=mb, clip_norm=None)
        p2, _, metr = step(params, st, batch, jnp.int32(0))
        outs.append((p2, float(metr["loss"])))
    # losses are means over microbatches -> equal; params very close
    assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-5)
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    d1, d2 = SyntheticTokens(cfg), SyntheticTokens(cfg)
    np.testing.assert_array_equal(d1.batch(5)["tokens"], d2.batch(5)["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticTokens(DataConfig(vocab_size=1000, seq_len=32,
                                    global_batch=8, n_hosts=2, host_id=0))
    h1 = SyntheticTokens(DataConfig(vocab_size=1000, seq_len=32,
                                    global_batch=8, n_hosts=2, host_id=1))
    b0, b1 = h0.batch(3), h1.batch(3)
    assert b0["tokens"].shape == (4, 32)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_shift():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = SyntheticTokens(cfg).batch(0)
    # next-token objective: labels are the one-step shift of the stream
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetch_preserves_order():
    it = prefetch(iter([{"x": np.array(i)} for i in range(10)]), depth=3)
    out = [int(b["x"]) for b in it]
    assert out == list(range(10))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d, keep=2)
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        for step in (10, 20, 30):
            store.save(step, tree, blocking=True)
        assert store.latest_step() == 30
        assert store.steps() == [20, 30]  # gc kept last 2
        restored, step = store.restore(tree)
        assert step == 30
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_ignores_partial_writes():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(5, {"x": jnp.zeros(3)}, blocking=True)
        # simulate a torn write of a newer step
        os.makedirs(os.path.join(d, "step_9.tmp"))
        assert store.latest_step() == 5


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(0, 1e-2, (257, 33)), jnp.float32)}
    err = init_error(g)
    comp, new_err = compress(g, err)
    deq = decompress(comp)
    # per-block int8: |error| <= scale/2 <= max|block|/254... loose bound:
    max_err = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    assert max_err <= float(jnp.max(jnp.abs(g["w"]))) / 127.0 + 1e-8
    # error feedback carries exactly the residual
    np.testing.assert_allclose(np.asarray(new_err["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-7)


def test_error_feedback_reduces_bias():
    """Repeated compression of the SAME gradient: error feedback makes the
    time-average of dequantized values converge to the true gradient."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1e-3, (64,)),
                          jnp.float32)}
    err = init_error(g)
    acc = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        comp, err = compress(g, err)
        acc = acc + decompress(comp)["w"]
    mean = acc / n
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g["w"]),
                               atol=float(jnp.max(jnp.abs(g["w"]))) / 40)
