"""Cluster-scale scheduling study: JASDA vs baselines with failures,
stragglers, and elastic capacity — the quantitative evaluation the paper
defers to future work, runnable on a laptop.  Includes a sweep of the three
unified policy presets (utilization / fairness / responsive) against the
balanced default, isolating what the CLEARING objective buys.

Run: PYTHONPATH=src python examples/cluster_study.py
"""
import numpy as np

from repro.core import (JasdaScheduler, Policy, SimConfig, SliceSpec,
                        make_workload, simulate)
from repro.core.baselines import (AuctionScheduler, BackfillScheduler,
                                  BestFitScheduler, FifoScheduler)

GB = 1 << 30


def pool():
    return ([SliceSpec("s20", 20 * GB, n_chips=4),
             SliceSpec("s10a", 10 * GB, n_chips=2),
             SliceSpec("s10b", 10 * GB, n_chips=2)]
            + [SliceSpec(f"s5{i}", 5 * GB, n_chips=1) for i in range(4)])


def workload():
    return make_workload(240, seed=1, arrival_rate=0.25,
                         work_range=(20.0, 150.0), mem_range_gb=(1.0, 14.0))


SYSTEMS = [("JASDA", lambda: JasdaScheduler(pool())),
           ("FIFO", lambda: FifoScheduler(pool())),
           ("EASY-backfill", lambda: BackfillScheduler(pool())),
           ("best-fit", lambda: BestFitScheduler(pool())),
           ("auction", lambda: AuctionScheduler(pool()))]


def run(title, **sim_kw):
    print(f"\n=== {title} ===")
    print(f"{'system':14s} {'util':>6s} {'meanJCT':>8s} {'p95':>8s} "
          f"{'jain':>6s} {'done':>8s}")
    for name, mk in SYSTEMS:
        res = simulate(mk(), workload(), SimConfig(seed=2, **sim_kw))
        print(f"{name:14s} {res.utilization:6.3f} {res.mean_jct:8.0f} "
              f"{res.p95_jct:8.0f} {res.jain_slowdown:6.3f} "
              f"{res.n_finished:4d}/{res.n_jobs}")


PRESETS = [("balanced", Policy),
           ("utilization", Policy.utilization),
           ("fairness", Policy.fairness),
           ("responsive", Policy.responsive)]


def run_presets(**sim_kw):
    """Sweep the unified policy presets on the same workload/slices."""
    print("\n=== JASDA policy presets (same workload, swapped Policy) ===")
    print(f"{'preset':14s} {'clearing':18s} {'util':>6s} {'meanJCT':>8s} "
          f"{'p95':>8s} {'jain':>6s} {'done':>8s}")
    for name, mk in PRESETS:
        policy = mk()
        res = simulate(JasdaScheduler(pool(), policy), workload(),
                       SimConfig(seed=2, **sim_kw))
        print(f"{name:14s} {policy.clearing.name:18s} {res.utilization:6.3f} "
              f"{res.mean_jct:8.0f} {res.p95_jct:8.0f} "
              f"{res.jain_slowdown:6.3f} {res.n_finished:4d}/{res.n_jobs}")


def main():
    run("steady state (heterogeneous MIG pool)", t_end=6000.0)
    run("with slice failures (MTBF ~5.5 min, repair 50 s)",
        t_end=9000.0, failure_rate=0.003)
    run_presets(t_end=6000.0)
    print("\nNote: monolithic baselines lose the WHOLE job on a failure; "
          "JASDA loses one chunk (atomization = checkpoint boundaries). "
          "Preset rows swap ONE Policy object: scoring weights, window "
          "ordering, age curve and the clearing backend move together.")


if __name__ == "__main__":
    main()
