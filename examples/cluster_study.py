"""Cluster-scale scheduling study: JASDA vs baselines with failures,
stragglers, and elastic capacity — the quantitative evaluation the paper
defers to future work, runnable on a laptop.  Includes a sweep of the three
unified policy presets (utilization / fairness / responsive) against the
balanced default, isolating what the CLEARING objective buys, and a
mixed-strategy population matchup (GreedyChunking vs AdaptiveBidder vs
ConservativeSafety) isolating what the BID side's feedback loop buys.

Run: PYTHONPATH=src python examples/cluster_study.py
"""
import numpy as np

from repro.core import (AdaptiveBidder, ConservativeSafety, GreedyChunking,
                        JasdaScheduler, Policy, SimConfig, SliceSpec,
                        make_workload, simulate)
from repro.core.baselines import (AuctionScheduler, BackfillScheduler,
                                  BestFitScheduler, FifoScheduler)
from repro.core.windows import WindowPolicy

GB = 1 << 30


def pool():
    return ([SliceSpec("s20", 20 * GB, n_chips=4),
             SliceSpec("s10a", 10 * GB, n_chips=2),
             SliceSpec("s10b", 10 * GB, n_chips=2)]
            + [SliceSpec(f"s5{i}", 5 * GB, n_chips=1) for i in range(4)])


def workload():
    return make_workload(240, seed=1, arrival_rate=0.25,
                         work_range=(20.0, 150.0), mem_range_gb=(1.0, 14.0))


SYSTEMS = [("JASDA", lambda: JasdaScheduler(pool())),
           ("FIFO", lambda: FifoScheduler(pool())),
           ("EASY-backfill", lambda: BackfillScheduler(pool())),
           ("best-fit", lambda: BestFitScheduler(pool())),
           ("auction", lambda: AuctionScheduler(pool()))]


def run(title, **sim_kw):
    print(f"\n=== {title} ===")
    print(f"{'system':14s} {'util':>6s} {'meanJCT':>8s} {'p95':>8s} "
          f"{'jain':>6s} {'done':>8s}")
    for name, mk in SYSTEMS:
        res = simulate(mk(), workload(), SimConfig(seed=2, **sim_kw))
        print(f"{name:14s} {res.utilization:6.3f} {res.mean_jct:8.0f} "
              f"{res.p95_jct:8.0f} {res.jain_slowdown:6.3f} "
              f"{res.n_finished:4d}/{res.n_jobs}")


PRESETS = [("balanced", Policy),
           ("utilization", Policy.utilization),
           ("fairness", Policy.fairness),
           ("responsive", Policy.responsive)]


def run_presets(**sim_kw):
    """Sweep the unified policy presets on the same workload/slices."""
    print("\n=== JASDA policy presets (same workload, swapped Policy) ===")
    print(f"{'preset':14s} {'clearing':18s} {'util':>6s} {'meanJCT':>8s} "
          f"{'p95':>8s} {'jain':>6s} {'done':>8s}")
    for name, mk in PRESETS:
        policy = mk()
        res = simulate(JasdaScheduler(pool(), policy), workload(),
                       SimConfig(seed=2, **sim_kw))
        print(f"{name:14s} {policy.clearing.name:18s} {res.utilization:6.3f} "
              f"{res.mean_jct:8.0f} {res.p95_jct:8.0f} "
              f"{res.jain_slowdown:6.3f} {res.n_finished:4d}/{res.n_jobs}")


def run_strategies(**sim_kw):
    """Mixed-strategy population: the bid-side negotiation matchup.

    One run, one scheduler — jobs differ ONLY in their BiddingStrategy
    (assigned round-robin by make_workload).  A short announcement horizon
    keeps windows contested, so the feedback loop (cutoffs, loss reasons,
    calibration bias) has something to adapt to.
    """
    print("\n=== mixed bidding strategies (same jobs, swapped strategy) ===")
    strategies = [GreedyChunking(), AdaptiveBidder(), ConservativeSafety()]
    sched = JasdaScheduler(pool(), Policy(window=WindowPolicy(horizon=60.0)))
    agents = make_workload(240, seed=1, arrival_rate=0.25,
                           work_range=(20.0, 150.0), mem_range_gb=(1.0, 14.0),
                           misreport_fraction=0.3, misreport_factor=1.5,
                           strategies=strategies)
    res = simulate(sched, agents, SimConfig(seed=2, **sim_kw))
    print(f"{'strategy':20s} {'jobs':>5s} {'done':>5s} {'bids':>6s} "
          f"{'wins':>6s} {'win%':>6s} {'cleared':>9s}")
    for name, row in sorted(res.strategy_stats.items()):
        wr = row["n_wins"] / max(row["n_bids"], 1)
        print(f"{name:20s} {row['n_jobs']:5d} {row['n_finished']:5d} "
              f"{row['n_bids']:6d} {row['n_wins']:6d} {wr:6.2f} "
              f"{row['score_won']:9.2f}")


def main():
    run("steady state (heterogeneous MIG pool)", t_end=6000.0)
    run("with slice failures (MTBF ~5.5 min, repair 50 s)",
        t_end=9000.0, failure_rate=0.003)
    run_presets(t_end=6000.0)
    run_strategies(t_end=6000.0)
    print("\nNote: monolithic baselines lose the WHOLE job on a failure; "
          "JASDA loses one chunk (atomization = checkpoint boundaries). "
          "Preset rows swap ONE Policy object: scoring weights, window "
          "ordering, age curve and the clearing backend move together; "
          "strategy rows swap ONE AgentConfig.strategy per job and read "
          "per-strategy outcomes off SimResult.strategy_stats.")


if __name__ == "__main__":
    main()
