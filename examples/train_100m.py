"""End-to-end training driver: a ~100M-parameter dense LM for a few hundred
steps, run AS A JASDA JOB under the executor — atomized into subjob chunks,
each chunk bid into scheduler-announced windows, executed for real, measured
(feeding ex-post verification), and checkpointed at chunk boundaries.

Run: PYTHONPATH=src python examples/train_100m.py --steps 300
(use --steps 20 for a quick smoke)
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.core import JasdaScheduler, SliceSpec
from repro.core.executor import JasdaExecutor, TrainingJob
from repro.core.scheduler import SchedulerConfig
from repro.core.windows import WindowPolicy
from repro.data import DataConfig, SyntheticTokens, prefetch
from repro.models import Model, ModelConfig
from repro.training import adamw, make_train_step, warmup_cosine

GB = 1 << 30


def build_model():
    """~100M params: 12L × d768 × 12H, 32k vocab (GPT-2-small class)."""
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=32768,
        model_axis_size=1, dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = build_model()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} {n_params/1e6:.1f}M params")

    opt = adamw(warmup_cosine(3e-4, 50, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt, microbatches=2))
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="ckpt_100m_")
    store = CheckpointStore(ckpt_dir)
    state = {"params": params, "opt": opt_state}

    # auto-resume (fault tolerance: kill this script and rerun)
    start = 0
    if store.latest_step() is not None:
        state, start = store.restore(state)
        print(f"resumed from checkpoint step {start}")

    losses = []

    def run_steps(s0, n):
        loss = None
        for i in range(s0 + start, s0 + start + n):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state["params"], state["opt"], m = step_fn(
                state["params"], state["opt"], batch, jnp.int32(i))
            loss = float(m["loss"])
            losses.append(loss)
        return {"loss": loss}

    def checkpoint(steps_done):
        store.save(start + steps_done,
                   {"params": state["params"], "opt": state["opt"]},
                   blocking=False)

    # ---- run under JASDA ---------------------------------------------------
    sched = JasdaScheduler(
        [SliceSpec("lane0", 8 * GB, n_chips=1)],
        SchedulerConfig(window=WindowPolicy(horizon=600.0, min_gap=0.3)))
    ex = JasdaExecutor(sched)
    job = TrainingJob(
        job_id=cfg.name, total_steps=args.steps - start, step_fn=run_steps,
        checkpoint_fn=checkpoint,
        param_bytes=n_params * 4.0, optimizer_bytes=n_params * 8.0,
        activation_bytes=args.batch * args.seq * cfg.d_model * 4.0 * 4,
        steps_per_sec=2.0)
    ex.register(job)
    ex.run(max_wall=3600.0)
    store.wait()

    print(f"\ndone: {job.steps_done} steps in {len(job.metrics_log)} JASDA chunks")
    print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f}")
    snap = sched.calibrator.snapshot()[cfg.name]
    print(f"job reliability after real measurements: rho={snap['rho']:.3f} "
          f"(verified chunks: {snap['n_verified']})")
    print(f"checkpoints in {ckpt_dir}: steps {store.steps()}")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
