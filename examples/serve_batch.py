"""Batched serving example: continuous batching over a slot-based KV cache.

Spins up a small decoder, submits a burst of requests with different prompt
lengths, and streams them through 4 shared slots — requests queue, claim
slots, decode together at mixed positions, and free slots on completion.

Run: PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, ModelConfig
from repro.serving import Request, ServeConfig, ServingEngine


def main():
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4,
                      d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
                      vocab_size=1024, model_axis_size=1, dtype=jnp.float32)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = ServingEngine(model, params,
                        ServeConfig(batch_slots=4, max_seq=128))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 24))
        reqs.append(Request(f"req-{i:02d}", prompt.astype(np.int32),
                            max_new_tokens=16))
        eng.submit(reqs[-1])

    t0 = time.perf_counter()
    steps = 0
    while True:
        active = eng.step()
        steps += 1
        if active == 0 and not eng.queue:
            break
    wall = time.perf_counter() - t0

    total_tokens = sum(len(r.output) for r in reqs)
    print(f"{len(reqs)} requests, {total_tokens} tokens generated in "
          f"{steps} engine steps ({wall:.2f}s, "
          f"{total_tokens / wall:.1f} tok/s on CPU)")
    for r in reqs[:3]:
        print(f"  {r.request_id}: prompt[{len(r.prompt)}] → {r.output}")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
