"""Quickstart: the JASDA interaction cycle end-to-end in 60 seconds.

1. Build a MIG-like slice pool.
2. Submit a mixed workload of jobs (each with an FMP memory profile).
3. Run the scheduler loop in simulation; print the audit trail + metrics.
4. Run the SAME schedule under FIFO for contrast.

Run: PYTHONPATH=src python examples/quickstart.py [--steps N]
"""
import argparse

from repro.core import (JasdaScheduler, SimConfig, SliceSpec, make_workload,
                        simulate)
from repro.core.baselines import FifoScheduler

GB = 1 << 30


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40, help="number of jobs")
    args = ap.parse_args()

    # a heterogeneous MIG-style pool: 1×20GB, 2×10GB, 4×5GB slices
    slices = [SliceSpec("s20", 20 * GB, n_chips=4),
              SliceSpec("s10a", 10 * GB, n_chips=2),
              SliceSpec("s10b", 10 * GB, n_chips=2)] + \
             [SliceSpec(f"s5{i}", 5 * GB, n_chips=1) for i in range(4)]

    print("=== JASDA (bid → clear → commit → verify) ===")
    sched = JasdaScheduler(slices)
    agents = make_workload(args.steps, seed=7, arrival_rate=0.3,
                           mem_range_gb=(1.0, 14.0))
    res = simulate(sched, agents, SimConfig(t_end=4000.0, seed=1))
    print("JASDA :", res.summary())

    # a few audit-trail rows (transparency, paper §5(f))
    rows = [r for r in sched.log if r.n_selected > 0][:5]
    print("\nfirst five clearing iterations:")
    for r in rows:
        print(f"  t={r.t:7.1f} window={r.window.slice_id:５}"
              f" bids={r.n_bids:2d} selected={r.n_selected} "
              f"total_score={r.total_score:.2f}")

    print("\nper-job reliability (ex-post verification, §4.2.1):")
    snap = sched.calibrator.snapshot()
    some = list(snap.items())[:5]
    for job, s in some:
        print(f"  {job}: rho={s['rho']:.3f} verified={s['n_verified']}")

    print("\n=== FIFO baseline (whole jobs, head-of-line) ===")
    agents = make_workload(args.steps, seed=7, arrival_rate=0.3,
                           mem_range_gb=(1.0, 14.0))
    res_f = simulate(FifoScheduler(slices), agents, SimConfig(t_end=4000.0, seed=1))
    print("FIFO  :", res_f.summary())


if __name__ == "__main__":
    main()
