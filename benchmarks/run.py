"""Benchmark harness — one function per paper table/claim + the deferred
quantitative study.  Prints ``name,us_per_call,derived`` CSV rows.

  table3_clearing        §4.5 worked example: exact reproduction + clearing latency
  wis_scaling            §4.6 O(M log M) clearing complexity
  lambda_policy          Table 2: λ ∈ {0.3, 0.5, 0.7} qualitative effects
  scheduler_comparison   §6(a) deferred study: JASDA vs FIFO/EASY/best-fit/auction
  calibration            §4.2.1: misreporting detection + win-rate suppression
  age_fairness           §4.3: β_age sweep vs starvation
  window_policies        §5.1(c): announcement-policy ablation
  atomization_ft         SJA thesis: work lost under failures vs monolithic
  round_throughput       round-batched clearing vs the single-window loop
                         (bids cleared/sec vs pool size — the PR 1 tentpole)
  policy_clearing        GreedyWIS vs GlobalAssignment backends on a
                         conflict-heavy pool: recovered utility + wall-clock
                         + replay-overhead gate (shared first pass + batched
                         lockstep replays vs the 9.34x PR-4 baseline)
  settle_throughput      device-resident settle: per-window host WIS loop vs
                         the batched multi-window dispatch at W x M grids
                         (identical selections + zero retraces — the PR 5
                         tentpole)
  adaptive_bidding       AdaptiveBidder vs GreedyChunking on a contended
                         cluster: per-strategy cleared score + win-rate over
                         the feedback loop (the PR 4 tentpole)
  score_dispatch         zero-recompile scoring: per-round latency + retrace
                         count across drifting M / λ / heterogeneous capacities
  pipeline_overlap       double-buffered round pipelining vs serial clearing
                         (host pack/WIS overlapped with device scoring)
  repartition_packing    dynamic repartitioning: FragmentationAware goodput
                         recovery on a fragmented inventory + StaticInventory
                         byte-identity + the EnergyAware proxy (PR 9 tentpole)
  migration_recovery     preemption-aware recovery: the revocation ladder
                         (migrate → preempt-with-credit → revoke-lossy) vs
                         drain-only loss + crash-identical resume across a
                         migration boundary (PR 10 tentpole)
  kernels                per-kernel µs/call (CPU interpret / reference paths)

Run: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick] [--list]
Rows are also written to BENCH_results.json (BENCH_quick.json with --quick)
for CI artifact upload.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Callable, Dict, List

import numpy as np


def _pin_xla_cpu_threads() -> None:
    """Single-thread XLA's CPU compute pool (before jax is first imported).

    On small CI boxes (2 cores) multi-threaded eigen fights the host python
    thread for every core, which turns the pipeline_overlap measurement into
    contention noise.  Pinning gives the host and the in-flight scoring
    stream one core each — the same separation a real host+TPU deployment
    has.  No-op if jax is already loaded or a TPU platform is requested.
    """
    if "jax" in sys.modules or "tpu" in os.environ.get("JAX_PLATFORMS", ""):
        return
    extra = "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + extra).strip()

def _force_host_devices() -> None:
    """Split the CPU backend into virtual XLA devices (before jax's first
    import) so the shard_scaling bench can exercise the mesh-sharded
    dispatch path on plain CPU runners.  ``JASDA_BENCH_SHARDS`` overrides
    the default 8.  No-op on real accelerators, when jax is already
    imported, or when the flag is already present in XLA_FLAGS.
    """
    if "jax" in sys.modules or "tpu" in os.environ.get("JAX_PLATFORMS", ""):
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return
    n = int(os.environ.get("JASDA_BENCH_SHARDS", "8"))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n} " + flags).strip()


ROWS: List[dict] = []
QUICK = False


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 2),
                 "derived": derived})
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def _time(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------
# §4.5 Table 3
# ---------------------------------------------------------------------------

def bench_table3_clearing():
    from repro.core.wis import wis_select
    starts, ends = [40, 47, 40], [47, 50, 50]
    scores = [0.67, 0.64, 0.72]
    sel, total = wis_select(starts, ends, scores)
    ok = set(sel.tolist()) == {0, 1} and abs(total - 1.31) < 1e-9
    us = _time(lambda: wis_select(starts, ends, scores), n=200)
    emit("table3_clearing", us,
         f"selected={{v_A1;v_A2}} total={total:.2f} paper_match={ok}")


# ---------------------------------------------------------------------------
# §4.6 complexity
# ---------------------------------------------------------------------------

def bench_wis_scaling():
    from repro.core.wis import wis_select
    rng = np.random.default_rng(0)
    prev = None
    for m in (256, 1024, 4096, 16384, 65536):
        starts = rng.uniform(0, 1000, m)
        ends = starts + rng.uniform(0.5, 30, m)
        w = rng.uniform(0, 1, m)
        us = _time(lambda: wis_select(starts, ends, w), n=3)
        ratio = us / prev if prev else float("nan")
        prev = us
        emit(f"wis_scaling_M{m}", us,
             f"x{ratio:.2f}_vs_prev(4x_M; ~4-5x=loglinear)")


# ---------------------------------------------------------------------------
# shared simulator scenarios
# ---------------------------------------------------------------------------

def _hetero_slices():
    from repro.core import SliceSpec
    GB = 1 << 30
    return ([SliceSpec("s20", 20 * GB, n_chips=4),
             SliceSpec("s10a", 10 * GB, n_chips=2),
             SliceSpec("s10b", 10 * GB, n_chips=2)]
            + [SliceSpec(f"s5{i}", 5 * GB, n_chips=1) for i in range(4)])


def _workload(n=240, seed=1, **kw):
    from repro.core import make_workload
    kw.setdefault("arrival_rate", 0.25)
    kw.setdefault("work_range", (20.0, 150.0))
    kw.setdefault("mem_range_gb", (1.0, 14.0))
    return make_workload(n, seed=seed, **kw)


def _run(sched_factory, *, sim_seed=2, t_end=6000.0, failure_rate=0.0,
         n=240, wl_kw=None):
    from repro.core import SimConfig, simulate
    t0 = time.perf_counter()
    res = simulate(sched_factory(), _workload(n, **(wl_kw or {})),
                   SimConfig(t_end=t_end, seed=sim_seed,
                             failure_rate=failure_rate))
    wall = (time.perf_counter() - t0) * 1e6
    return res, wall


# ---------------------------------------------------------------------------
# Table 2: λ sweep
# ---------------------------------------------------------------------------

def bench_lambda_policy():
    from repro.core import JasdaScheduler, ScoringPolicy
    from repro.core.scheduler import SchedulerConfig
    for lam, label in ((0.3, "utilization-first"), (0.5, "balanced"),
                       (0.7, "qos-first")):
        mk = lambda lam=lam: JasdaScheduler(
            _hetero_slices(), SchedulerConfig(scoring=ScoringPolicy(lam=lam)))
        res, wall = _run(mk)
        emit(f"lambda_{lam}", wall,
             f"{label}: util={res.utilization:.3f} meanJCT={res.mean_jct:.0f} "
             f"p95={res.p95_jct:.0f} jain={res.jain_slowdown:.3f}")


# ---------------------------------------------------------------------------
# §6(a): the deferred comparison study
# ---------------------------------------------------------------------------

def bench_scheduler_comparison():
    from repro.core import JasdaScheduler
    from repro.core.baselines import (AuctionScheduler, BackfillScheduler,
                                      BestFitScheduler, FifoScheduler)
    systems = [("jasda", lambda: JasdaScheduler(_hetero_slices()))] + [
        (c.name, (lambda c=c: c(_hetero_slices())))
        for c in (FifoScheduler, BackfillScheduler, BestFitScheduler,
                  AuctionScheduler)]
    for name, mk in systems:
        res, wall = _run(mk)
        emit(f"compare_{name}", wall,
             f"util={res.utilization:.3f} meanJCT={res.mean_jct:.0f} "
             f"p95={res.p95_jct:.0f} jain={res.jain_slowdown:.3f} "
             f"finished={res.n_finished}/{res.n_jobs}")


def bench_atomization_ft():
    """Fault tolerance: atomization (JASDA) vs whole-job restart baselines."""
    from repro.core import JasdaScheduler
    from repro.core.baselines import BackfillScheduler
    for rate in (0.001, 0.003, 0.006):
        for name, mk in (("jasda", lambda: JasdaScheduler(_hetero_slices())),
                         ("backfill", lambda: BackfillScheduler(_hetero_slices()))):
            res, wall = _run(mk, failure_rate=rate, t_end=9000.0)
            emit(f"ft_{name}_fail{rate}", wall,
                 f"meanJCT={res.mean_jct:.0f} p95={res.p95_jct:.0f} "
                 f"finished={res.n_finished}/{res.n_jobs}")


def bench_fault_recovery():
    """Robustness layer: goodput retained under a seeded FaultPlan (slice
    revocations + silent/erroring bidders) vs the fault-free run, and crash
    -at-round-k checkpoint recovery replaying byte-identically.  Gated by
    check_regression.py (``fault_recovery_`` prefix)."""
    import tempfile

    from repro.checkpoint import CheckpointStore
    from repro.core import (FaultEvent, FaultPlan, JasdaScheduler, SimConfig,
                            simulate)
    from repro.core.faults import SCHEDULER_CRASH

    n, t_end = (60, 1500.0) if QUICK else (160, 4000.0)
    slices = _hetero_slices()
    plan = FaultPlan.generate(
        17, t_end=t_end,
        slice_ids=[s.slice_id for s in slices],
        job_ids=[f"J{i:03d}" for i in range(n)],
        revoke_rate=0.0015, silent_rate=0.001, error_rate=0.001,
        repair_time=60.0, fault_duration=25.0)
    cfg = SimConfig(t_end=t_end, seed=2)

    t0 = time.perf_counter()
    base = simulate(JasdaScheduler(_hetero_slices()), _workload(n, seed=3), cfg)
    faulted = simulate(JasdaScheduler(_hetero_slices()), _workload(n, seed=3),
                       cfg, faults=plan)
    wall = (time.perf_counter() - t0) * 1e6

    # goodput = completed useful work per unit makespan (committed score
    # would double-count revoked-then-recleared work)
    def goodput(r):
        done = sum(r.scheduler.agents[j].spec.total_work for j in r.jct_per_job)
        return done / max(r.makespan, 1e-9)

    retained = goodput(faulted) / max(goodput(base), 1e-9)
    lost = sum(1 for row in faulted.scheduler.commit_log
               if row.status == "lost")
    emit("fault_recovery_goodput", wall,
         f"goodput_retained={retained:.3f} lost_commitments={lost} "
         f"finished={faulted.n_finished}/{faulted.n_jobs} "
         f"vs_faultfree={base.n_finished}/{base.n_jobs}")

    crash_plan = FaultPlan(seed=17, events=plan.events + (
        FaultEvent(t=t_end / 3 + 0.5, kind=SCHEDULER_CRASH),))
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        r_ref = simulate(JasdaScheduler(_hetero_slices()),
                         _workload(n, seed=3), cfg, faults=plan,
                         checkpoint=CheckpointStore(d1), checkpoint_every=25)
        r_crash = simulate(JasdaScheduler(_hetero_slices()),
                           _workload(n, seed=3), cfg, faults=crash_plan,
                           checkpoint=CheckpointStore(d2), checkpoint_every=25)
    wall = (time.perf_counter() - t0) * 1e6
    identical = (r_crash.jct_per_job == r_ref.jct_per_job
                 and r_crash.calibration == r_ref.calibration
                 and r_crash.total_score == r_ref.total_score
                 and [(row.status, row.job_id, row.slice_id, row.score)
                      for row in r_crash.scheduler.commit_log]
                 == [(row.status, row.job_id, row.slice_id, row.score)
                     for row in r_ref.scheduler.commit_log])
    emit("fault_recovery_crash_replay", wall,
         f"crash_identical={identical} "
         f"n_committed={r_crash.n_committed}/{r_ref.n_committed}")


def bench_repartition_packing():
    """Dynamic repartitioning (core/repartition.py).  Two gated rows
    (``repartition_`` prefix in check_regression.py):

    * a min_capacity-heavy workload on a packed (2x4-chip) vs fragmented
      (8x1-chip) inventory: the FragmentationAware policy must recover
      goodput the fragmented static run strands (``recovered_ok``), the
      StaticInventory run must be byte-identical to the subsystem being
      off entirely (``static_identical``), and the fragmentation
      trajectory is reported peak→end;
    * EnergyAware consolidate-and-gate on a light workload: the energy
      proxy must undercut the always-on static run with every job still
      finishing (``energy_ok``).

    All metrics are simulated-time/score quantities — machine speed
    cancels entirely.
    """
    from repro.core import (EnergyAware, FragmentationAware, JasdaScheduler,
                            SimConfig, SliceSpec, StaticInventory,
                            make_workload, simulate)

    GB = 1 << 30
    n, t_end = (30, 400.0) if QUICK else (80, 1200.0)

    def packed():
        return [SliceSpec("big0", 20 * GB, n_chips=4),
                SliceSpec("big1", 20 * GB, n_chips=4)]

    def fragmented():  # the same 8-chip pod, maximally split
        return [SliceSpec(f"f{k}", 5 * GB, n_chips=1) for k in range(8)]

    def wl():  # ~60% of jobs need more than one 5 GB chip
        return make_workload(n, seed=3, arrival_rate=0.5,
                             work_range=(5.0, 40.0), mem_range_gb=(1.0, 4.0),
                             min_capacity_fraction=0.6,
                             min_capacity_range_gb=(12.0, 18.0))

    def run(slices, policy):
        return simulate(JasdaScheduler(slices), wl(),
                        SimConfig(t_end=t_end, seed=2, repartition=policy))

    def goodput(r):  # completed work per unit horizon (shared across runs)
        done = sum(r.scheduler.agents[j].spec.total_work for j in r.jct_per_job)
        return done / t_end

    def key(r):
        return ([(row.status, row.job_id, row.slice_id, row.t_start,
                  row.t_end, row.score) for row in r.scheduler.commit_log],
                r.jct_per_job, r.total_score)

    t0 = time.perf_counter()
    r_packed = run(packed(), StaticInventory())
    r_off = run(fragmented(), None)
    r_static = run(fragmented(), StaticInventory())
    r_aware = run(fragmented(), FragmentationAware())
    wall = (time.perf_counter() - t0) * 1e6
    frags = [f for _, f in r_aware.repartition.frag_trace]
    emit("repartition_packing", wall,
         f"goodput_packed={goodput(r_packed):.3f} "
         f"goodput_frag_static={goodput(r_static):.3f} "
         f"goodput_frag_aware={goodput(r_aware):.3f} "
         f"recovered_ok={goodput(r_aware) > goodput(r_static)} "
         f"static_identical={key(r_off) == key(r_static)} "
         f"frag_peak={max(frags):.3f} frag_end={frags[-1]:.3f} "
         f"n_merges={r_aware.repartition.n_merges} "
         f"finished={r_aware.n_finished}/{r_aware.n_jobs} "
         f"vs_static={r_static.n_finished}/{r_static.n_jobs}")

    def light():  # fits 1-chip slices; most of the pod sits idle
        return make_workload(max(n // 4, 6), seed=3, arrival_rate=1.0,
                             work_range=(5.0, 15.0), mem_range_gb=(1.0, 4.0))

    t0 = time.perf_counter()
    e_static = simulate(JasdaScheduler(fragmented()), light(),
                        SimConfig(t_end=t_end, seed=2,
                                  repartition=StaticInventory()))
    e_aware = simulate(JasdaScheduler(fragmented()), light(),
                       SimConfig(t_end=t_end, seed=2,
                                 repartition=EnergyAware()))
    wall = (time.perf_counter() - t0) * 1e6
    ratio = (e_aware.repartition.energy_joules
             / max(e_static.repartition.energy_joules, 1e-9))
    st = e_aware.repartition.stats()
    emit("repartition_energy", wall,
         f"energy_ratio={ratio:.3f} "
         f"energy_ok={ratio < 1.0 and e_aware.n_finished == e_aware.n_jobs} "
         f"n_gates={st['n_gates']:.0f} n_merges={st['n_merges']:.0f} "
         f"finished={e_aware.n_finished}/{e_aware.n_jobs}")


def bench_migration_recovery():
    """Preemption-aware recovery (the revocation ladder).  Two gated rows
    (``migration_`` prefix in check_regression.py):

    * the same seeded slice-revocation schedule run drain-only vs with
      the ladder armed (MigrationPlanner + checkpointable jobs): the
      ladder must retain strictly more goodput (``ladder_ok``), with the
      work-saved ratio and per-rung counts reported;
    * a crash-at-round-k checkpoint recovery whose restore point spans a
      completed migration: the resumed run must replay byte-identically
      (``crash_identical``).

    All comparison metrics are simulated-time quantities — machine speed
    cancels.
    """
    import tempfile

    from repro.checkpoint import CheckpointStore
    from repro.core import (FaultEvent, FaultPlan, JasdaScheduler,
                            MigrationConfig, SimConfig, simulate)
    from repro.core.faults import SCHEDULER_CRASH

    n, t_end = (60, 1500.0) if QUICK else (160, 4000.0)
    slices = _hetero_slices()
    plan = FaultPlan.generate(
        17, t_end=t_end, slice_ids=[s.slice_id for s in slices],
        revoke_rate=0.0015, repair_time=60.0)
    # jobs checkpoint every 8 work units: an interrupted chunk keeps its
    # completed granules (preempt-with-credit rung)
    wl = lambda: _workload(n, seed=3, preempt_granularity=8.0)  # noqa: E731
    cfg_off = SimConfig(t_end=t_end, seed=2)
    cfg_on = SimConfig(t_end=t_end, seed=2, migration=MigrationConfig())

    t0 = time.perf_counter()
    r_off = simulate(JasdaScheduler(_hetero_slices()), wl(), cfg_off,
                     faults=plan)
    r_on = simulate(JasdaScheduler(_hetero_slices()), wl(), cfg_on,
                    faults=plan)
    wall = (time.perf_counter() - t0) * 1e6

    def goodput(r):  # completed useful work per unit makespan
        done = sum(r.scheduler.agents[j].spec.total_work for j in r.jct_per_job)
        return done / max(r.makespan, 1e-9)

    retained = goodput(r_on) / max(goodput(r_off), 1e-9)
    # fraction of the workload's total work the ladder saved from
    # re-execution (granule credit on doomed chunks; the drain-only run
    # redoes all of it, paying in makespan)
    total = sum(a.spec.total_work for a in r_on.scheduler.agents.values())
    saved = r_on.work_credited / max(total, 1e-9)
    emit("migration_recovery_ladder", wall,
         f"goodput_retained={retained:.3f} work_saved={saved:.3f} "
         f"ladder_ok={goodput(r_on) > goodput(r_off)} "
         f"n_migrated={r_on.n_migrated} n_preempted={r_on.n_preempted} "
         f"work_credited={r_on.work_credited:.1f} "
         f"lost={r_on.n_lost_commitments}/{r_off.n_lost_commitments} "
         f"finished={r_on.n_finished}/{r_on.n_jobs} "
         f"vs_drain={r_off.n_finished}/{r_off.n_jobs}")

    crash_plan = FaultPlan(seed=17, events=plan.events + (
        FaultEvent(t=t_end / 3 + 0.5, kind=SCHEDULER_CRASH),))
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        r_ref = simulate(JasdaScheduler(_hetero_slices()), wl(), cfg_on,
                         faults=plan,
                         checkpoint=CheckpointStore(d1), checkpoint_every=25)
        r_crash = simulate(JasdaScheduler(_hetero_slices()), wl(), cfg_on,
                           faults=crash_plan,
                           checkpoint=CheckpointStore(d2), checkpoint_every=25)
    wall = (time.perf_counter() - t0) * 1e6
    identical = (r_crash.jct_per_job == r_ref.jct_per_job
                 and r_crash.calibration == r_ref.calibration
                 and r_crash.total_score == r_ref.total_score
                 and (r_crash.n_migrated, r_crash.n_preempted,
                      r_crash.work_credited)
                 == (r_ref.n_migrated, r_ref.n_preempted, r_ref.work_credited)
                 and [(row.status, row.job_id, row.slice_id, row.score)
                      for row in r_crash.scheduler.commit_log]
                 == [(row.status, row.job_id, row.slice_id, row.score)
                     for row in r_ref.scheduler.commit_log])
    emit("migration_recovery_crash_replay", wall,
         f"crash_identical={identical} "
         f"migrated={r_ref.n_migrated} preempted={r_ref.n_preempted} "
         f"n_committed={r_crash.n_committed}/{r_ref.n_committed}")


def bench_service_latency():
    """Streaming service mode: open-loop Poisson soak SLOs.  Two gated rows
    (``service_latency_`` prefix in check_regression.py):

    * p99 announce→award decision latency at 0.7x capacity, plus a
      double-run determinism check (identical award log + stats);
    * goodput retained under 2.0x overload with bounded-queue admission
      vs the 1.0x run, with the accept-all control degrading below it
      (blown QoS deadlines waste capacity).
    """
    from repro.core import JasdaScheduler
    from repro.service import (AcceptAll, BoundedQueue, JasdaService,
                               PoissonArrivals, ServiceConfig)

    t_end = 240.0 if QUICK else 480.0
    # cluster capacity ~12 work/s; log-uniform work (8,40) mean ~19.9
    rate_1x = 12.0 / 19.88

    def soak(rate, admission, qos=0.3, slack=(3.0, 8.0), bucket=512):
        arr = PoissonArrivals(rate, seed=5, work_range=(8.0, 40.0),
                              mem_range_gb=(1.0, 12.0), qos_fraction=qos,
                              deadline_slack=slack)
        svc = JasdaService(
            JasdaScheduler(_hetero_slices()), arr,
            config=ServiceConfig(t_end=t_end, seed=5, max_bucket_m=bucket),
            admission=admission)
        stats = svc.run()
        key = ([(r.round, r.t, r.variant_id, r.job_id, r.slice_id)
                for r in svc.award_log], stats)
        return stats, key

    t0 = time.perf_counter()
    st, key_a = soak(0.7 * rate_1x, AcceptAll())
    _, key_b = soak(0.7 * rate_1x, AcceptAll())
    wall = (time.perf_counter() - t0) * 1e6
    emit("service_latency_p99", wall,
         f"p50={st.announce_award_p50:.3f} p95={st.announce_award_p95:.3f} "
         f"p99={st.announce_award_p99:.3f} goodput={st.goodput:.3f} "
         f"completed={st.n_completed}/{st.n_arrived} "
         f"deterministic={key_a == key_b}")

    t0 = time.perf_counter()
    ov = dict(qos=1.0, slack=(1.0, 2.0), bucket=128)
    base, _ = soak(rate_1x, AcceptAll(), **ov)
    bounded, _ = soak(2 * rate_1x, BoundedQueue(), **ov)
    flood, _ = soak(2 * rate_1x, AcceptAll(), **ov)
    wall = (time.perf_counter() - t0) * 1e6
    retained = bounded.goodput / max(base.goodput, 1e-9)
    retained_flood = flood.goodput / max(base.goodput, 1e-9)
    shed_frac = bounded.n_shed / max(bounded.n_arrived, 1)
    overload_ok = (retained >= 0.9 and retained_flood < retained - 0.05
                   and bounded.n_shed > 0)
    emit("service_latency_overload", wall,
         f"goodput_retained={retained:.3f} "
         f"acceptall_retained={retained_flood:.3f} "
         f"shed_fraction={shed_frac:.3f} "
         f"expired={flood.n_expired}/{bounded.n_expired} "
         f"overload_ok={overload_ok}")


# ---------------------------------------------------------------------------
# §4.2.1 calibration
# ---------------------------------------------------------------------------

def bench_calibration():
    from repro.core import CalibrationConfig, JasdaScheduler, SimConfig, simulate
    from repro.core.scheduler import SchedulerConfig
    for label, cal in (
        ("off", CalibrationConfig(mode="fixed", gamma=1.0)),
        ("k3", CalibrationConfig(mode="reliability", kappa=3.0)),
        ("k6", CalibrationConfig(mode="reliability", kappa=6.0)),
    ):
        sched = JasdaScheduler(_hetero_slices(),
                               SchedulerConfig(calibration=cal))
        agents = _workload(160, seed=3, misreport_fraction=0.5,
                           misreport_factor=1.8)
        t0 = time.perf_counter()
        simulate(sched, agents, SimConfig(t_end=6000.0, seed=2))
        wall = (time.perf_counter() - t0) * 1e6
        snap = sched.calibrator.snapshot()
        mis = [s["rho"] for j, s in snap.items()
               if sched.agents.get(j) and sched.agents[j].cfg.misreport > 1]
        hon = [s["rho"] for j, s in snap.items()
               if sched.agents.get(j) and sched.agents[j].cfg.misreport <= 1]
        wins_mis = np.mean([a.n_wins for a in sched.agents.values()
                            if a.cfg.misreport > 1])
        wins_hon = np.mean([a.n_wins for a in sched.agents.values()
                            if a.cfg.misreport <= 1])
        emit(f"calibration_{label}", wall,
             f"rho_honest={np.mean(hon):.3f} rho_misrep={np.mean(mis):.3f} "
             f"wins_ratio_mis/hon={wins_mis/max(wins_hon,1e-9):.2f}")


# ---------------------------------------------------------------------------
# §4.3 age / fairness
# ---------------------------------------------------------------------------

def bench_age_fairness():
    from repro.core import JasdaScheduler, ScoringPolicy
    from repro.core.scheduler import SchedulerConfig
    for b_age in (0.0, 0.2, 0.4):
        betas = {"utilization": 0.4 - b_age / 2, "slack": 0.1,
                 "mem_headroom": 0.05, "energy": 0.05, "age": b_age}
        mk = lambda b=betas: JasdaScheduler(
            _hetero_slices(),
            SchedulerConfig(scoring=ScoringPolicy(lam=0.5, betas=b)))
        res, wall = _run(mk)
        emit(f"age_beta{b_age}", wall,
             f"p95JCT={res.p95_jct:.0f} jain={res.jain_slowdown:.3f} "
             f"meanJCT={res.mean_jct:.0f}")


# ---------------------------------------------------------------------------
# §5.1(c) window announcement policies
# ---------------------------------------------------------------------------

def bench_window_policies():
    from repro.core import JasdaScheduler
    from repro.core.scheduler import SchedulerConfig
    from repro.core.windows import WindowPolicy
    for kind in ("earliest", "largest", "best_fit", "slack"):
        mk = lambda k=kind: JasdaScheduler(
            _hetero_slices(), SchedulerConfig(window=WindowPolicy(kind=k)))
        res, wall = _run(mk)
        emit(f"window_{kind}", wall,
             f"util={res.utilization:.3f} meanJCT={res.mean_jct:.0f} "
             f"jain={res.jain_slowdown:.3f}")


# ---------------------------------------------------------------------------
# round-batched clearing vs the legacy single-window loop (the tentpole)
# ---------------------------------------------------------------------------

def bench_round_throughput():
    """Bids cleared/sec: per-window numpy loop vs one batched round.

    Builds 8 windows on 8 slices with pooled bid sets of growing size, then
    times (a) the pre-refactor hot path — ``clear_window`` per window with
    per-variant numpy scoring — against (b) ``clear_round``'s single batched
    scoring dispatch + per-window WIS.  Selections are cross-checked for
    equality, so the speedup is measured on identical outcomes.
    """
    from repro.core import ScoringPolicy, Window, clear_round, clear_window
    from repro.core.trp import fmp_standard
    from repro.core.types import Variant

    GB = 1 << 30
    policy = ScoringPolicy()
    rng = np.random.default_rng(7)
    n_windows = 8
    # disjoint windows (distinct slices AND time ranges): round and legacy
    # must produce identical selections — no cross-window conflicts by
    # construction, so the comparison is pure mechanism overhead
    windows = [
        Window(slice_id=f"s{k}", capacity=(6 + 2 * k) * GB,
               t_min=200.0 * k, duration=150.0)
        for k in range(n_windows)
    ]

    def make_pool(m: int):
        n_jobs = max(8, m // 8)
        fmps = [fmp_standard(1 * GB, (1.5 + 3 * rng.uniform()) * GB, 0.2 * GB)
                for _ in range(n_jobs)]
        ages = {f"J{j}": float(rng.uniform(0, 1)) for j in range(n_jobs)}
        pool = []
        for i in range(m):
            j = i % n_jobs
            w = windows[rng.integers(0, n_windows)]
            t0 = w.t_min + rng.uniform(0, w.duration * 0.7)
            dur = rng.uniform(2.0, (w.t_min + w.duration - t0))
            pool.append(Variant(
                job_id=f"J{j}", slice_id=w.slice_id, t_start=t0, duration=dur,
                fmp=fmps[j], local_utility=float(rng.uniform(0.1, 0.9)),
                declared_features={}, payload={"work": dur},
                variant_id=f"J{j}/v{i}"))
        return pool, ages

    sizes = (64, 256) if QUICK else (64, 256, 1024)
    reps = 5 if QUICK else 7
    for m in sizes:
        pool, ages = make_pool(m)

        def legacy():
            return [clear_window(w, pool, policy, ages=ages) for w in windows]

        def batched():
            return clear_round(windows, pool, policy, ages=ages)

        sel_legacy = [tuple(v.variant_id for v in r.selected) for r in legacy()]
        rr = batched()
        sel_round = [tuple(v.variant_id for v in r.selected) for r in rr.results]
        identical = sel_legacy == sel_round
        # the speedup claim is only meaningful on identical outcomes — make
        # CI smoke fail loudly if the paths ever diverge
        assert identical, (
            f"round/legacy selections diverged at M={m}: {sel_round} vs {sel_legacy}"
        )

        # ABBA-paired minima (see pipeline_overlap): sandboxed CI jitter
        # inflates samples multiplicatively, so the fastest observed run of
        # each path is the faithful comparison
        us_l_r, us_r_r = [], []
        for i in range(reps):
            first, second = (legacy, batched) if i % 2 == 0 else (batched, legacy)
            a = _time(first, n=1, warmup=0)
            b = _time(second, n=1, warmup=0)
            l, r = (a, b) if i % 2 == 0 else (b, a)
            us_l_r.append(l)
            us_r_r.append(r)
        us_l, us_r = min(us_l_r), min(us_r_r)
        speedup = us_l / max(us_r, 1e-9)
        emit(f"round_throughput_M{m}", us_r,
             f"bids/s={m / (us_r / 1e6):.0f} single_window_us={us_l:.0f} "
             f"speedup={speedup:.2f}x identical_selections={identical}")


# ---------------------------------------------------------------------------
# policy-driven clearing: greedy vs global assignment (the PR 3 tentpole)
# ---------------------------------------------------------------------------

# serial-replay GlobalAssignment overhead measured before the PR-5 replay
# fan-out (policy_clearing_M256, PR-4 baseline_quick.json) — the overhead_ok
# gate requires staying measurably below it
OVERHEAD_BASELINE = 9.34


def bench_policy_clearing():
    """Recovered utility + wall-clock: GreedyWIS vs GlobalAssignment.

    Builds windows sharing ONE time range across slices and a pool in which
    each job bids the same time span on several slices — exactly the
    cross-window conflict pattern ``run_round`` produces when agents answer
    the full window set.  Greedy conflict resolution keeps each job's
    best-scored win and re-clears; the assignment backend searches which
    window each conflicted job should keep.  The bench asserts
    ``GlobalAssignment`` total ≥ ``GreedyWIS`` total (the backend's
    dominance contract — CI-gated via ``recovered_ok``) and emits the
    recovered score plus both backends' wall-clock.
    """
    from repro.core import ScoringPolicy, Window, clear_round
    from repro.core.policy import GlobalAssignment, GreedyWIS
    from repro.core.trp import fmp_standard
    from repro.core.types import Variant

    GB = 1 << 30
    policy = ScoringPolicy()
    rng = np.random.default_rng(13)
    n_windows = 6
    # one shared time range: bids on different slices CAN overlap in time,
    # so multi-slice bidders conflict by construction
    windows = [Window(slice_id=f"s{k}", capacity=(6 + 2 * k) * GB,
                      t_min=0.0, duration=200.0) for k in range(n_windows)]

    def make_pool(m: int):
        n_jobs = max(6, m // 12)
        fmps = [fmp_standard(1 * GB, (1.5 + 2.5 * rng.uniform()) * GB, 0.2 * GB)
                for _ in range(n_jobs)]
        ages = {f"J{j}": float(rng.uniform(0, 1)) for j in range(n_jobs)}
        pool = []
        while len(pool) < m:
            j = int(rng.integers(0, n_jobs))
            t0 = float(rng.uniform(0, 140.0))
            dur = float(rng.uniform(5.0, min(60.0, 200.0 - t0)))
            # the same span bid on 2-3 slices (one bid per window max)
            for k in rng.choice(n_windows, size=int(rng.integers(2, 4)),
                                replace=False):
                if len(pool) >= m:
                    break
                pool.append(Variant(
                    job_id=f"J{j}", slice_id=f"s{k}", t_start=t0,
                    duration=dur, fmp=fmps[j],
                    local_utility=float(rng.uniform(0.1, 0.9)),
                    declared_features={}, payload={"work": dur},
                    variant_id=f"J{j}/s{k}/v{len(pool)}"))
        return pool, ages

    sizes = (256,) if QUICK else (256, 1024)
    reps = 5 if QUICK else 7
    greedy_backend, ga_backend = GreedyWIS(), GlobalAssignment()
    for m in sizes:
        pool, ages = make_pool(m)

        def greedy():
            return clear_round(windows, pool, policy, ages=ages,
                               clearing=greedy_backend)

        def global_assign():
            return clear_round(windows, pool, policy, ages=ages,
                               clearing=ga_backend)

        def global_assign_batched():
            # the PR-5 replay fan-out: candidate-config replays share one
            # packed buffer set + first pass and run in lockstep through
            # the batched selector (one dispatch per config generation)
            return clear_round(windows, pool, policy, ages=ages,
                               clearing=ga_backend, wis_impl="numpy")

        g, a = greedy(), global_assign()
        ab = global_assign_batched()
        recovered = a.total_score - g.total_score
        ok = recovered >= -1e-9
        # the backend's dominance contract: fail CI smoke loudly if the
        # assignment search ever clears less than greedy
        assert ok, (
            f"GlobalAssignment lost score at M={m}: "
            f"{a.total_score:.6f} < {g.total_score:.6f}")
        sel_a = [tuple(v.variant_id for v in r.selected) for r in a.results]
        sel_b = [tuple(v.variant_id for v in r.selected) for r in ab.results]
        assert sel_a == sel_b, (
            f"batched-selector GlobalAssignment diverged at M={m}")

        # ABBA-paired minima (see round_throughput): sandbox jitter only
        # inflates samples, so per-variant minima compare capabilities
        us_g_r, us_a_r, us_b_r = [], [], []
        for i in range(reps):
            first, second = (greedy, global_assign) if i % 2 == 0 else \
                (global_assign, greedy)
            x = _time(first, n=1, warmup=0)
            y = _time(second, n=1, warmup=0)
            gg, aa = (x, y) if i % 2 == 0 else (y, x)
            us_g_r.append(gg)
            us_a_r.append(aa)
            us_b_r.append(_time(global_assign_batched, n=1, warmup=0))
        us_g, us_a, us_b = min(us_g_r), min(us_a_r), min(us_b_r)
        overhead = us_a / max(us_g, 1e-9)
        overhead_b = us_b / max(us_g, 1e-9)
        # PR-5 gate: the BATCHED replay path must stay measurably below the
        # serial-replay baseline (9.34x, PR-4 era) on the SAME scenario —
        # the serial 'overhead=' field is separately tolerance-gated by
        # check_regression, so gating the batched field here means neither
        # path can regress unnoticed
        overhead_ok = overhead_b < OVERHEAD_BASELINE
        emit(f"policy_clearing_M{m}", us_a,
             f"greedy_us={us_g:.0f} overhead={overhead:.2f}x "
             f"batched_us={us_b:.0f} overhead_batched={overhead_b:.2f}x "
             f"greedy_total={g.total_score:.4f} "
             f"global_total={a.total_score:.4f} recovered={recovered:.4f} "
             f"conflicts={g.n_conflicts} recovered_ok={ok} "
             f"overhead_ok={overhead_ok}")


# ---------------------------------------------------------------------------
# bid-side negotiation: AdaptiveBidder vs GreedyChunking (the PR 4 tentpole)
# ---------------------------------------------------------------------------

def bench_adaptive_bidding():
    """Mixed-strategy contention scenario: does the feedback loop pay?

    Paired identical jobs — same work, FMP, arrival; only the job_id and
    the ``BiddingStrategy`` differ — compete on a scarce 2-slice cluster
    with a short announcement horizon (windows are genuinely contested
    every round, not time-multiplexed into a long future).  The adaptive
    half consumes the scheduler's ``RoundFeedback`` (per-window cutoffs,
    loss reasons) to shrink its chunk scale and re-target windows online;
    the greedy half bids the historical largest-fit chains.

    The bench asserts the tentpole's market claim — AdaptiveBidder
    STRICTLY improves its own total cleared score over GreedyChunking over
    ≥20 rounds (``adaptive_ok`` is the CI gate in check_regression.py) —
    and emits both groups' cleared score and win-rate.  Deterministic:
    fixed seeds, serial-equivalent pipelined rounds.
    """
    from repro.core import (AdaptiveBidder, AgentConfig, GreedyChunking,
                            JasdaScheduler, JobAgent, JobSpec, Policy,
                            SimConfig, SliceSpec, simulate)
    from repro.core.trp import fmp_standard
    from repro.core.windows import WindowPolicy

    GB = 1 << 30
    rng = np.random.default_rng(5)
    slices = [SliceSpec("s0", 8 * GB, n_chips=1),
              SliceSpec("s1", 6 * GB, n_chips=1)]
    agents = []
    for i in range(5):
        mem = (1.5 + 2.0 * rng.uniform()) * GB
        fmp = fmp_standard(0.5 * GB, mem, 0.1 * GB, rel_sigma=0.03)
        for tag, strat in (("A", AdaptiveBidder()), ("G", GreedyChunking())):
            spec = JobSpec(job_id=f"J{tag}{i}", arrival_time=0.0,
                           total_work=40.0, fmp=fmp)
            agents.append(JobAgent(spec, AgentConfig(strategy=strat)))

    sched = JasdaScheduler(slices, Policy(window=WindowPolicy(horizon=40.0)))
    t0 = time.perf_counter()
    res = simulate(sched, agents, SimConfig(t_end=300.0, seed=2))
    wall = (time.perf_counter() - t0) * 1e6

    adaptive = res.strategy_stats["adaptive"]
    greedy = res.strategy_stats["greedy_chunking"]
    advantage = adaptive["score_won"] - greedy["score_won"]
    # the tentpole's market claim, CI-gated via adaptive_ok: emit the row
    # either way (check_regression fails it with the numbers attached —
    # an in-bench assert would abort the remaining quick benches blind)
    ok = advantage > 0 and res.iterations >= 20
    wr_a = adaptive["n_wins"] / max(adaptive["n_bids"], 1)
    wr_g = greedy["n_wins"] / max(greedy["n_bids"], 1)
    emit("adaptive_bidding_contention", wall,
         f"adaptive_total={adaptive['score_won']:.4f} "
         f"greedy_total={greedy['score_won']:.4f} advantage={advantage:.4f} "
         f"winrate_adaptive={wr_a:.3f} winrate_greedy={wr_g:.3f} "
         f"rounds={res.iterations} "
         f"finished={adaptive['n_finished'] + greedy['n_finished']}/10 "
         f"adaptive_ok={ok}")


# ---------------------------------------------------------------------------
# device-resident settle: batched multi-window WIS vs the per-window host loop
# ---------------------------------------------------------------------------

def bench_settle_throughput():
    """Batched multi-window settle vs the per-window host WIS loop.

    Builds W×M grids (W disjoint windows, M pooled scored bids) and clears
    them through ``settle_round`` with (a) the historical per-window
    ``wis_select`` host loop and (b) the batched ``RoundSelector`` backends
    ("numpy" host float64 and the "ref" device dispatch).  Selections are
    asserted identical across all backends — the settle move is a pure
    mechanism change — and the batched sweep (pack + one dispatch for all
    windows) is timed against the host loop.  A second pass over ≥8 rounds
    of drifting (W, M, scores) asserts the device dispatch NEVER retraces
    after its per-bucket warmup (the zero-recompile contract of
    kernels/wis_dp, mirroring score_dispatch).
    """
    import jax
    from repro.core import ScoringPolicy
    from repro.core.clearing import assign_bids, settle_round
    from repro.core.trp import fmp_standard
    from repro.core.types import Variant, Window
    from repro.core.wis import make_round_selector, wis_select
    from repro.core.policy.base import _pool_members
    from repro.kernels.wis_dp import ops as wis_ops

    GB = 1 << 30
    rng = np.random.default_rng(17)
    device_impl = "pallas" if jax.default_backend() == "tpu" else "ref"

    def make(m, n_windows):
        windows = [
            Window(slice_id=f"s{k}", capacity=(6 + 2 * (k % 8)) * GB,
                   t_min=200.0 * k, duration=150.0)
            for k in range(n_windows)
        ]
        fmp = fmp_standard(1 * GB, 2 * GB, 0.2 * GB)
        pool = []
        for i in range(m):
            w = windows[rng.integers(0, n_windows)]
            t0 = w.t_min + rng.uniform(0, w.duration * 0.7)
            dur = rng.uniform(2.0, (w.t_min + w.duration - t0))
            pool.append(Variant(
                job_id=f"J{i % 64}", slice_id=w.slice_id, t_start=t0,
                duration=dur, fmp=fmp, local_utility=0.5,
                declared_features={}, payload={"work": dur},
                variant_id=f"J{i % 64}/v{i}"))
        fit, win_idx, view = assign_bids(windows, pool)
        # float32-exact 12-bit score grid: every partial DP sum over ≤4096
        # lanes stays exactly representable in float32, so the float32
        # device DP and the float64 host DP provably make identical
        # decisions (ties included) and the identical-selections asserts
        # below can never trip on rounding
        scores = rng.integers(1, 1 << 12, len(fit)).astype(np.float64) / (1 << 12)
        return windows, fit, win_idx, view, scores

    host = make_round_selector(None)
    batched = make_round_selector("numpy")
    device = make_round_selector(device_impl)
    reps = 5 if QUICK else 7
    # wide rounds (many slices → many windows) are where the batched settle
    # pays: more rows vectorize together AND the per-window lane count (the
    # sequential DP depth) shrinks
    grids = ((48, 1024), (64, 2048)) if QUICK else \
        ((16, 1024), (32, 2048), (48, 1024), (64, 2048), (64, 4096))
    for n_windows, m in grids:
        windows, fit, win_idx, view, scores = make(m, n_windows)
        members = _pool_members(n_windows, win_idx)
        banned = np.zeros(len(fit), bool)
        all_rows = list(range(n_windows))

        def host_sweep():
            # the pre-PR-5 per-window hot loop of fixed_point_settle
            out = []
            for k in all_rows:
                ia = np.asarray(members[k], np.intp)
                sel, _ = wis_select(view.t_start[ia], view.t_end[ia], scores[ia])
                out.append([members[k][int(j)] for j in np.asarray(sel)])
            return out

        def batched_sweep(rs):
            packed = rs.pack(members, view, scores)
            return rs.select(packed, all_rows, banned)

        # identical selections: sweep-level AND full settle_round-level
        ref_sweep = host_sweep()
        assert ref_sweep == batched_sweep(batched) == batched_sweep(device), \
            f"batched sweep diverged at W={n_windows} M={m}"
        base_rr = settle_round(windows, fit, win_idx, scores,
                               selector=host, view=view)
        for rs in (batched, device):
            rr = settle_round(windows, fit, win_idx, scores,
                              selector=rs, view=view)
            assert ([tuple(v.variant_id for v in r.selected) for r in rr.results]
                    == [tuple(v.variant_id for v in r.selected)
                        for r in base_rr.results]), \
                f"settle diverged under {rs!r} at W={n_windows} M={m}"

        us_h_r, us_b_r, us_d_r = [], [], []
        for i in range(reps):
            # ABBA-paired minima (see round_throughput)
            first, second = (host_sweep, lambda: batched_sweep(batched)) \
                if i % 2 == 0 else (lambda: batched_sweep(batched), host_sweep)
            x = _time(first, n=1, warmup=0)
            y = _time(second, n=1, warmup=0)
            h, b = (x, y) if i % 2 == 0 else (y, x)
            us_h_r.append(h)
            us_b_r.append(b)
            us_d_r.append(_time(lambda: batched_sweep(device), n=1, warmup=0))
        us_h, us_b, us_d = min(us_h_r), min(us_b_r), min(us_d_r)
        emit(f"settle_throughput_W{n_windows}_M{m}", us_b,
             f"host_loop_us={us_h:.0f} speedup={us_h / max(us_b, 1e-9):.2f}x "
             f"device_us={us_d:.0f} device_speedup={us_h / max(us_d, 1e-9):.2f}x "
             f"impl={device_impl} identical_selections=True")

    # zero-retrace contract: ≥8 drifting (W, M, scores) rounds after a
    # per-bucket warmup must never miss the settle jit cache
    drift = [(8, 700), (4, 300), (6, 1024), (5, 512), (8, 650),
             (4, 280), (6, 990), (5, 480), (7, 800), (3, 200)]
    packs = {}
    for i, (nw, m) in enumerate(drift):
        windows, fit, win_idx, view, scores = make(m, nw)
        members = _pool_members(nw, win_idx)
        packed = device.pack(members, view, scores)
        packs[i] = (packed, list(range(nw)), np.zeros(len(fit), bool))
    for i in range(len(drift)):  # warmup pass: one compile per shape bucket
        device.select(packs[i][0], packs[i][1], packs[i][2])
    base = wis_ops.trace_counts()
    for i in range(len(drift)):  # measured pass: same buckets, fresh dispatch
        device.select(packs[i][0], packs[i][1], packs[i][2])
    delta = {k: wis_ops.trace_counts()[k] - base[k] for k in base}
    retraces = sum(delta.values())
    assert retraces == 0, f"batched settle retraced: {delta}"
    emit("settle_throughput_retraces", 0.0,
         f"rounds={len(drift)} retraces=0 impl={device_impl}")


# ---------------------------------------------------------------------------
# zero-recompile scoring dispatch: runtime (λ, capacity, θ) + M-bucketing
# ---------------------------------------------------------------------------

def bench_score_dispatch():
    """Per-round dispatch latency + retrace count across drifting shapes.

    Runs ≥8 consecutive rounds with varying pool sizes, λ values and
    heterogeneous per-window capacities/θ.  Because λ/capacity/θ are traced
    runtime operands and M pads to power-of-two buckets, the jit cache must
    be hit on EVERY round after the per-bucket warmup — the bench asserts
    ZERO retraces (one compiled executable per M-bucket) and emits the
    per-round latency.
    """
    import jax
    from repro.kernels.jasda_score import ops

    rng = np.random.default_rng(3)
    t = 32
    impl = "pallas" if jax.default_backend() == "tpu" else "ref"

    def make_args(m):
        fj = rng.uniform(0, 1, (m, 3)).astype(np.float32)
        fs = rng.uniform(0, 1, (m, 3)).astype(np.float32)
        al = np.array([.5, .3, .2], np.float32)
        be = np.array([.4, .2, .2], np.float32)
        mu = rng.uniform(5, 19, (m, t)).astype(np.float32)
        sg = rng.uniform(0.01, .5, (m, t)).astype(np.float32)
        caps = rng.choice([12.0, 16.0, 20.0, 24.0], m)  # heterogeneous slices
        ths = rng.choice([0.02, 0.05, 0.1], m)
        return fj, fs, al, be, mu, sg, caps, ths

    def dispatch(args, lam):
        fj, fs, al, be, mu, sg, caps, ths = args
        s, e, _ = ops.score_variants(fj, fs, al, be, mu, sg, lam=lam,
                                     capacity=caps, theta=ths, impl=impl)
        np.asarray(s)  # block: measure completed rounds, not dispatch alone

    # drifting pool sizes (λ varies every round, capacities every variant)
    rounds = [(300, 0.30), (512, 0.50), (700, 0.70), (900, 0.40),
              (1024, 0.60), (333, 0.55), (768, 0.45), (512, 0.35),
              (1000, 0.50), (256, 0.65)]
    buckets = sorted({ops.bucket_m(m) for m, _ in rounds})
    for b in buckets:  # one-time compile per bucket
        dispatch(make_args(b), 0.5)

    base = ops.trace_counts()
    args_per_round = [make_args(m) for m, _ in rounds]
    for i, ((m, lam), args) in enumerate(zip(rounds, args_per_round)):
        # min over reps: sandbox jitter only inflates samples
        us = min(_time(lambda a=args, l=lam: dispatch(a, l), n=1, warmup=0)
                 for _ in range(3 if QUICK else 5))
        emit(f"score_dispatch_r{i}_M{m}", us,
             f"bucket={ops.bucket_m(m)} lam={lam} hetero_caps=4 impl={impl}")
    delta = {k: ops.trace_counts()[k] - base[k] for k in base}
    retraces = sum(delta.values())
    # the tentpole claim: fail CI loudly if the cache is ever missed again
    assert retraces == 0, f"scoring dispatch retraced: {delta}"
    emit("score_dispatch_retraces", 0.0,
         f"rounds={len(rounds)} retraces=0 executables={len(buckets)} "
         f"buckets={buckets}")


# ---------------------------------------------------------------------------
# round pipelining: host pack/clear overlapped with in-flight device scoring
# ---------------------------------------------------------------------------

def bench_pipeline_overlap():
    """Pipelined vs serial wall-clock over a stream of scoring rounds.

    Streams K independent rounds (8 windows, M pooled bids each, FMP grids
    packed so the in-flight dispatch carries real per-variant safety work)
    through ``pipelined_clear_rounds`` and through serial ``clear_round``
    calls.  Selections are asserted byte-identical; the speedup is pure
    overlap of round k+1's host packing + round k-1's WIS clearing with
    round k's device scoring.
    """
    from repro.core import ScoringPolicy, Window, clear_round
    from repro.core.pipeline import pipelined_clear_rounds
    from repro.core.trp import fmp_standard
    from repro.core.types import Variant
    from repro.kernels.jasda_score.ops import FMPGridCache

    GB = 1 << 30
    policy = ScoringPolicy()
    rng = np.random.default_rng(11)
    n_windows = 8
    windows = [
        Window(slice_id=f"s{k}", capacity=(10 + 2 * k) * GB,
               t_min=300.0 * k, duration=200.0)
        for k in range(n_windows)
    ]

    def make_round(m):
        n_jobs = max(8, m // 16)
        fmps = [fmp_standard(1 * GB, (1.5 + 2.5 * rng.uniform()) * GB, 0.2 * GB)
                for _ in range(n_jobs)]
        pool = []
        for i in range(m):
            j = i % n_jobs
            w = windows[rng.integers(0, n_windows)]
            t0 = w.t_min + rng.uniform(0, w.duration * 0.7)
            dur = rng.uniform(2.0, (w.t_min + w.duration - t0))
            pool.append(Variant(
                job_id=f"J{j}", slice_id=w.slice_id, t_start=t0, duration=dur,
                fmp=fmps[j], local_utility=float(rng.uniform(0.1, 0.9)),
                declared_features={}, payload={"work": dur},
                variant_id=f"J{j}/v{i}"))
        return windows, pool

    sizes = (2048,) if QUICK else (2048, 4096)
    n_rounds = 8
    reps = 7
    for m in sizes:
        rounds = [make_round(m) for _ in range(n_rounds)]
        cache = FMPGridCache(maxsize=4096)
        # the production kernel path (Pallas; interpret-lowered off-TPU) with
        # grids packed at the TRP default resolution: the in-flight dispatch
        # carries the full (M, T) per-variant-capacity safety reduction
        kw = dict(score_impl="pallas", recheck_theta=0.5, grid=64,
                  grid_cache=cache)

        def serial():
            return [clear_round(w, p, policy, **kw) for w, p in rounds]

        def piped():
            return pipelined_clear_rounds(rounds, policy, **kw)

        sel_s = [[tuple(v.variant_id for v in r.selected) for r in rr.results]
                 for rr in serial()]
        sel_p = [[tuple(v.variant_id for v in r.selected) for r in rr.results]
                 for rr in piped()]
        assert sel_s == sel_p, f"pipelined selections diverged at M={m}"

        # paired reps in ABBA order, median of per-pair ratios: sandboxed CI
        # kernels add heavy multiplicative jitter that adjacent samples
        # share (the ratio cancels it), alternating the order cancels the
        # slow load-dependent drift, and the median rejects unpaired spikes
        ts_r, tp_r = [], []
        for i in range(reps):
            first, second = (serial, piped) if i % 2 == 0 else (piped, serial)
            a = _time(first, n=1, warmup=0)
            b = _time(second, n=1, warmup=0)
            s, p = (a, b) if i % 2 == 0 else (b, a)
            ts_r.append(s)
            tp_r.append(p)
            time.sleep(0.05)  # let the sandbox scheduler settle between pairs
        ratios = sorted(p / max(s, 1e-9) for s, p in zip(ts_r, tp_r))
        med_ratio = ratios[len(ratios) // 2]
        ts, tp = min(ts_r), min(tp_r)
        # min/min is the headline ratio: sandbox noise only ever INFLATES a
        # sample, so the fastest observed run of each variant is the faithful
        # capability comparison; the median pair ratio stays as a noise gauge
        ratio = tp / max(ts, 1e-9)
        emit(f"pipeline_overlap_M{m}", tp,
             f"serial_us={ts:.0f} ratio={ratio:.2f} "
             f"median_pair_ratio={med_ratio:.2f} rounds={n_rounds} "
             f"reps={reps} identical_selections=True")


# ---------------------------------------------------------------------------
# mesh-sharded auction dispatches: million-bid rounds across virtual devices
# ---------------------------------------------------------------------------

def bench_shard_scaling():
    """Sharded (auction mesh) vs single-device round dispatches at M ≥ 1e5.

    Times the two device halves of a round — the pooled-bid scoring
    dispatch and the window-sharded fused settle — unsharded vs sharded
    over ``make_auction_mesh(8)`` (8 virtual CPU devices; see
    ``_force_host_devices``).  Byte-identity of every output and the
    zero-retrace contract (one executable per pow2 bucket per mesh shape)
    are ASSERTED; the timing ratio is reported as ``scaling=``.

    NOTE (CI): 1–2-core runners time-slice the 8 virtual devices on one
    physical core, so ``scaling`` here measures dispatch overhead and
    cache locality (per-shard working sets fit cache, which already makes
    the sharded path faster at M=2^20), NOT parallel speedup.  On real
    multi-device platforms the same dispatches scale near-linearly (≥3x at
    8 shards); CI gates byte-identity and retraces exactly and the ratio
    only against the committed same-environment baseline — the
    pipeline_overlap precedent.
    """
    import jax
    from repro.kernels.jasda_score import ops as score_ops
    from repro.kernels.wis_dp import ops as wis_ops
    from repro.kernels.wis_dp.ops import wis_settle_fused
    from repro.launch.mesh import make_auction_mesh, mesh_chips

    rng = np.random.default_rng(29)
    mesh = make_auction_mesh(8)
    shards = mesh_chips(mesh)
    impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    t = 32
    reps = 3 if QUICK else 5

    def score_args(m):
        fj = rng.uniform(0, 1, (m, 3)).astype(np.float32)
        fs = rng.uniform(0, 1, (m, 3)).astype(np.float32)
        al = np.array([.5, .3, .2], np.float32)
        be = np.array([.4, .2, .2], np.float32)
        mu = rng.uniform(5, 19, (m, t)).astype(np.float32)
        sg = rng.uniform(0.01, .5, (m, t)).astype(np.float32)
        caps = rng.choice([12.0, 16.0, 20.0, 24.0], m)
        ths = rng.choice([0.02, 0.05, 0.1], m)
        return fj, fs, al, be, mu, sg, caps, ths

    def score_dispatch(args, mm):
        fj, fs, al, be, mu, sg, caps, ths = args
        s, e, _ = score_ops.score_variants(
            fj, fs, al, be, mu, sg, lam=.5, capacity=caps, theta=ths,
            impl=impl, mesh=mm)
        return np.asarray(s), np.asarray(e)

    def settle_layout(m, n_windows, lanes):
        # synthetic sorted-lane layout over an M-pool: ends ascending per
        # row (the pack invariant), bounded predecessor counts, random
        # pool-index gather targets and ~10% masked lanes
        starts = rng.uniform(0, 900, (n_windows, lanes))
        ends = np.sort(starts + rng.uniform(1, 40, (n_windows, lanes)), axis=1)
        starts = np.minimum(starts, ends - 1e-3)
        pred = np.stack([
            np.searchsorted(ends[w], starts[w], side="right")
            for w in range(n_windows)]).astype(np.int32)
        pred = np.minimum(pred, np.arange(lanes, dtype=np.int32)[None, :])
        idx = rng.integers(0, m, (n_windows, lanes)).astype(np.int32)
        mask = rng.random((n_windows, lanes)) > 0.1
        return idx, mask, pred

    sizes = (1 << 17, 1 << 20)
    for m in sizes:
        args = score_args(m)
        s0 = score_dispatch(args, None)
        s1 = score_dispatch(args, mesh)
        assert all(np.array_equal(a, b) for a, b in zip(s0, s1)), \
            f"sharded scoring diverged at M={m}"
        us_u, us_s = [], []
        for i in range(reps):
            # ABBA-paired minima (see pipeline_overlap): jitter only inflates
            first, second = (None, mesh) if i % 2 == 0 else (mesh, None)
            a = _time(lambda mm=first: score_dispatch(args, mm), n=1, warmup=0)
            b = _time(lambda mm=second: score_dispatch(args, mm), n=1, warmup=0)
            u, s = (a, b) if i % 2 == 0 else (b, a)
            us_u.append(u)
            us_s.append(s)
        us_un, us_sh = min(us_u), min(us_s)
        emit(f"shard_scaling_score_M{m}", us_sh,
             f"unsharded_us={us_un:.0f} scaling={us_un / max(us_sh, 1e-9):.2f} "
             f"shards={shards} impl={impl} identical_selections=True")

    # fused settle: weights gathered from the M=2^20 in-flight scores,
    # window rows sharded, scores replicated across shards
    m = sizes[-1]
    scores32 = (rng.integers(1, 1 << 12, m) / (1 << 12)).astype(np.float32)
    n_windows, lanes = 256, 1024
    idx, mask, pred = settle_layout(m, n_windows, lanes)

    def settle_dispatch(mm):
        sel, tot = wis_settle_fused(scores32, idx, mask, pred, impl=impl,
                                    mesh=mm)
        return np.asarray(sel), np.asarray(tot)

    r0 = settle_dispatch(None)
    r1 = settle_dispatch(mesh)
    assert np.array_equal(r0[0], r1[0]) and np.array_equal(r0[1], r1[1]), \
        "sharded fused settle diverged"
    us_u, us_s = [], []
    for i in range(reps):
        first, second = (None, mesh) if i % 2 == 0 else (mesh, None)
        a = _time(lambda mm=first: settle_dispatch(mm), n=1, warmup=0)
        b = _time(lambda mm=second: settle_dispatch(mm), n=1, warmup=0)
        u, s = (a, b) if i % 2 == 0 else (b, a)
        us_u.append(u)
        us_s.append(s)
    us_un, us_sh = min(us_u), min(us_s)
    emit(f"shard_scaling_settle_W{n_windows}_M{m}", us_sh,
         f"unsharded_us={us_un:.0f} scaling={us_un / max(us_sh, 1e-9):.2f} "
         f"shards={shards} lanes={lanes} impl={impl} "
         f"identical_selections=True")

    # zero-retrace: fresh same-bucket rounds (different M, new data) after
    # the warmups above must never miss either jit cache, sharded or not
    base = (score_ops.trace_counts(), wis_ops.trace_counts())
    args2 = score_args((1 << 20) - 4097)
    a0 = score_dispatch(args2, None)
    a1 = score_dispatch(args2, mesh)
    assert all(np.array_equal(x, y) for x, y in zip(a0, a1))
    idx2, mask2, pred2 = settle_layout(m, n_windows, lanes)
    idx, mask, pred = idx2, mask2, pred2
    b0 = settle_dispatch(None)
    b1 = settle_dispatch(mesh)
    assert np.array_equal(b0[0], b1[0])
    after = (score_ops.trace_counts(), wis_ops.trace_counts())
    retraces = sum(after[j][k] - base[j][k] for j in range(2)
                   for k in base[j])
    assert retraces == 0, f"sharded dispatch retraced: {base} -> {after}"
    emit("shard_scaling_retraces", 0.0,
         f"retraces=0 shards={shards} buckets={[1 << 17, 1 << 20]} impl={impl}")


# ---------------------------------------------------------------------------
# kernels (CPU timings: interpret for pallas paths, XLA for refs)
# ---------------------------------------------------------------------------

def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ref import mha_reference
    from repro.kernels.linear_scan.ref import linear_scan_associative
    from repro.kernels.jasda_score.ops import score_variants
    from repro.kernels.wis_dp.ops import wis_clear

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    k = jax.random.normal(ks[1], (1, 2, 512, 64))
    v = jax.random.normal(ks[2], (1, 2, 512, 64))
    f = jax.jit(lambda q, k, v: mha_reference(q, k, v))
    us = _time(lambda: jax.block_until_ready(f(q, k, v)), n=10)
    emit("kernel_attention_ref_512", us, "B1H4S512D64 (XLA oracle path)")

    a = jax.random.uniform(ks[0], (2, 1024, 256), jnp.float32, 0.9, 0.999)
    b = jax.random.normal(ks[1], (2, 1024, 256))
    f2 = jax.jit(lambda a, b: linear_scan_associative(a, b)[0])
    us = _time(lambda: jax.block_until_ready(f2(a, b)), n=10)
    emit("kernel_linear_scan_assoc_1024", us, "B2T1024D256")

    rng = np.random.default_rng(0)
    m, t = 512, 64
    args = (rng.uniform(0, 1, (m, 3)).astype(np.float32),
            rng.uniform(0, 1, (m, 3)).astype(np.float32),
            np.array([.5, .3, .2], np.float32),
            np.array([.4, .2, .2], np.float32),
            rng.uniform(5, 19, (m, t)).astype(np.float32),
            rng.uniform(0, .5, (m, t)).astype(np.float32))
    us = _time(lambda: score_variants(*args, lam=.6, capacity=20., theta=.05,
                                      impl="ref"), n=10)
    emit("kernel_jasda_score_M512", us, f"M={m} T={t} (paper hot loop)")

    starts = rng.uniform(0, 1000, 2048)
    ends = starts + rng.uniform(1, 30, 2048)
    w = rng.uniform(0, 1, 2048)
    us = _time(lambda: wis_clear(starts, ends, w, impl="ref"), n=5)
    emit("kernel_wis_clear_M2048", us, "sort+DP+backtrack")


# ---------------------------------------------------------------------------

BENCHES: Dict[str, Callable] = {
    "table3_clearing": bench_table3_clearing,
    "wis_scaling": bench_wis_scaling,
    "lambda_policy": bench_lambda_policy,
    "scheduler_comparison": bench_scheduler_comparison,
    "calibration": bench_calibration,
    "age_fairness": bench_age_fairness,
    "window_policies": bench_window_policies,
    "atomization_ft": bench_atomization_ft,
    "fault_recovery": bench_fault_recovery,
    "repartition_packing": bench_repartition_packing,
    "migration_recovery": bench_migration_recovery,
    "service_latency": bench_service_latency,
    "round_throughput": bench_round_throughput,
    "policy_clearing": bench_policy_clearing,
    "adaptive_bidding": bench_adaptive_bidding,
    "settle_throughput": bench_settle_throughput,
    "score_dispatch": bench_score_dispatch,
    "pipeline_overlap": bench_pipeline_overlap,
    "shard_scaling": bench_shard_scaling,
    "kernels": bench_kernels,
}

# CI smoke subset: fast, no multi-minute simulator sweeps
QUICK_BENCHES = ("table3_clearing", "round_throughput", "policy_clearing",
                 "adaptive_bidding", "settle_throughput", "score_dispatch",
                 "pipeline_overlap", "shard_scaling", "kernels",
                 "fault_recovery", "service_latency", "repartition_packing",
                 "migration_recovery")


def main() -> None:
    global QUICK
    _pin_xla_cpu_threads()
    _force_host_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fast subset + reduced sizes")
    ap.add_argument("--list", action="store_true",
                    help="list benchmark names (* = in the --quick subset) and exit")
    ap.add_argument("--json", default=None,
                    help="output path (default BENCH_results.json / BENCH_quick.json)")
    args = ap.parse_args()
    if args.list:
        for name in BENCHES:
            print(f"{name}{' *' if name in QUICK_BENCHES else ''}")
        return
    QUICK = args.quick
    if args.only and args.only not in BENCHES:
        ap.error(f"unknown benchmark {args.only!r}; choose from: "
                 + ", ".join(BENCHES))
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        if args.quick and not args.only and name not in QUICK_BENCHES:
            continue
        fn()
    out = args.json or ("BENCH_quick.json" if args.quick else "BENCH_results.json")
    with open(out, "w") as f:
        json.dump(ROWS, f, indent=2)
    print(f"# wrote {out}", flush=True)


if __name__ == "__main__":
    main()
