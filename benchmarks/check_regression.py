"""Benchmark regression gate for CI.

Compares the freshly-written BENCH JSON against a committed baseline
(``benchmarks/baseline_quick.json``) and fails (exit 1) on regression.

Two classes of check:

* **Relative metrics** (tight, default ±25% via ``--tol``): computed
  within one benchmark run, so machine-speed differences between the
  baseline host and the CI runner cancel.
    - ``round_throughput_*``: the ``speedup=``x over the single-window
      loop may not drop more than ``tol`` below baseline, and
      ``identical_selections=True`` must hold.
    - ``score_dispatch_retraces``: must report ``retraces=0`` — the
      zero-recompile contract is exact, no tolerance.
    - ``pipeline_overlap_*``: the pipelined/serial ``ratio=`` must stay
      ≤ ``--max-overlap-ratio`` (default 1.0: pipelining must never
      regress into a slowdown).  The ~0.65–0.8x capability numbers in
      ROADMAP.md were measured on an unloaded host; under co-tenant load
      a 2-core runner cannot physically overlap, so CI does not gate at
      0.8 (tighten via ``BENCH_MAX_OVERLAP_RATIO`` on quiet runners).
    - ``policy_clearing_*``: ``recovered_ok=True`` must hold — the
      ``GlobalAssignment`` backend may never clear LESS total score than
      ``GreedyWIS`` (its dominance contract is exact, no tolerance) —
      and the deterministic ``recovered=`` score may not drop more than
      ``tol`` below baseline.  ``overhead_ok=True`` must hold (the replay
      overhead vs greedy stays below the pre-PR-5 9.34x serial-replay
      baseline) and the measured ``overhead=`` ratio may not grow more
      than ``tol`` above the committed baseline.
    - ``settle_throughput_*``: ``identical_selections=True`` must hold
      (the batched device settle is a pure mechanism change), the
      ``speedup=``x over the per-window host WIS loop may not drop more
      than ``tol`` below baseline, and ``settle_throughput_retraces``
      must report ``retraces=0`` (exact — the zero-recompile contract of
      the batched settle dispatch).
    - ``shard_scaling_*``: ``identical_selections=True`` must hold (the
      mesh-sharded dispatch is byte-identical to single-device, exact),
      ``shard_scaling_retraces`` must report ``retraces=0``, and the
      sharded/unsharded ``scaling=`` ratio may not drop more than ``tol``
      below baseline.  CI runners time-slice the 8 virtual devices on 1-2
      physical cores, so the gated ratio reflects dispatch overhead and
      cache locality, not the ≥3x real multi-device scaling (the
      pipeline_overlap precedent).
    - ``adaptive_bidding_*``: ``adaptive_ok=True`` must hold — the
      ``AdaptiveBidder`` strategy must strictly out-clear
      ``GreedyChunking`` on the contention scenario (the negotiation
      feedback loop's value contract, exact) — and the deterministic
      ``advantage=`` score gap may not drop more than ``tol`` below
      baseline.
    - ``service_latency_*``: ``deterministic=True`` must hold (two
      fixed-seed soaks produce identical award logs + stats, exact) and
      ``overload_ok=True`` must hold (bounded-queue admission retains
      ≥90% of the 1.0x goodput at 2.0x overload while accept-all
      degrades below it, exact); the ``p99=`` decision latency and
      ``goodput_retained=`` are gated relative to baseline — both are
      simulated-time metrics, so machine speed cancels entirely.
    - ``repartition_*``: ``static_identical=True`` must hold (a
      StaticInventory run is byte-identical to the repartition subsystem
      being off entirely, exact), ``recovered_ok=True`` must hold
      (FragmentationAware out-goodputs the static run on the fragmented
      inventory, exact) and ``energy_ok=True`` must hold (EnergyAware's
      tick-sampled energy proxy undercuts static with every job still
      finishing, exact); the recovered ``goodput_frag_aware=`` and the
      ``energy_ratio=`` are gated relative to baseline (simulated-time
      metrics).
    - ``migration_*``: ``ladder_ok=True`` must hold (the revocation
      ladder — migrate → preempt-with-credit → revoke-lossy — retains
      strictly more goodput than drain-only loss under the same seeded
      revocation schedule, exact) and ``crash_identical=True`` must hold
      (a crash-at-round-k resume whose restore point spans a completed
      migration replays byte-identically, exact); ``goodput_retained=``
      and the ``work_saved=`` fraction are gated relative to baseline
      (simulated-time metrics).

* **Absolute latency** (loose, default 5x via ``--us-tol``):
  ``us_per_call`` of gated rows against baseline.  Shared CI runners and
  the baseline host differ in speed AND jitter by 2-4x run-to-run, so
  this only catches order-of-magnitude regressions (e.g. the jit cache
  silently disabled, which costs 10-100x per round); tighten with
  ``BENCH_US_TOL`` when baseline and runner are the same quiet machine.

A gated row missing from the fresh results is itself a failure.
Regenerate the baseline with:

    python -m benchmarks.run --quick
    cp BENCH_quick.json benchmarks/baseline_quick.json

Usage:
    python -m benchmarks.check_regression BENCH_quick.json \
        benchmarks/baseline_quick.json [--tol 0.25] [--us-tol 1.0]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

GATED_PREFIXES = ("round_throughput_", "score_dispatch_", "pipeline_overlap_",
                  "policy_clearing_", "adaptive_bidding_", "settle_throughput_",
                  "shard_scaling_", "fault_recovery_", "service_latency_",
                  "repartition_", "migration_")


def _load(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r for r in rows}


def _field(row: dict, key: str):
    m = re.search(rf"\b{key}=(-?[0-9.]+)", row.get("derived", ""))
    return float(m.group(1)) if m else None


def check(fresh: dict, baseline: dict, tol: float, us_tol: float,
          max_overlap_ratio: float) -> list:
    failures = []

    for name, base_row in sorted(baseline.items()):
        if not name.startswith(GATED_PREFIXES):
            continue
        row = fresh.get(name)
        if row is None:
            failures.append(f"{name}: gated row missing from fresh results")
            continue

        if name in ("score_dispatch_retraces", "settle_throughput_retraces",
                    "shard_scaling_retraces"):
            if "retraces=0" not in row.get("derived", ""):
                failures.append(
                    f"{name}: expected retraces=0, got {row.get('derived')!r}")
            continue

        if name.startswith("settle_throughput_"):
            if "identical_selections=True" not in row.get("derived", ""):
                failures.append(f"{name}: selections no longer identical")
            base_sp, sp = _field(base_row, "speedup"), _field(row, "speedup")
            if base_sp and sp and sp < base_sp * (1.0 - tol):
                failures.append(
                    f"{name}: batched-settle speedup {sp:.2f}x vs baseline "
                    f"{base_sp:.2f}x (-{(1 - sp / base_sp) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")

        if name.startswith("shard_scaling_"):
            # byte-identity is exact; the sharded/unsharded timing ratio is
            # gated only relative to the committed same-environment baseline
            # (1-2-core CI time-slices the virtual devices — see the bench
            # docstring; real multi-device scaling is a capability number)
            if "identical_selections=True" not in row.get("derived", ""):
                failures.append(f"{name}: sharded round no longer identical")
            base_sc, sc = _field(base_row, "scaling"), _field(row, "scaling")
            if base_sc and sc and sc < base_sc * (1.0 - tol):
                failures.append(
                    f"{name}: sharded scaling {sc:.2f}x vs baseline "
                    f"{base_sc:.2f}x (-{(1 - sc / base_sc) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")

        if name.startswith("round_throughput_"):
            if "identical_selections=True" not in row.get("derived", ""):
                failures.append(f"{name}: selections no longer identical")
            base_sp, sp = _field(base_row, "speedup"), _field(row, "speedup")
            if base_sp and sp and sp < base_sp * (1.0 - tol):
                failures.append(
                    f"{name}: speedup {sp:.2f}x vs baseline {base_sp:.2f}x "
                    f"(-{(1 - sp / base_sp) * 100:.0f}% > {tol * 100:.0f}% tolerance)")

        if name.startswith("policy_clearing_"):
            if "recovered_ok=True" not in row.get("derived", ""):
                failures.append(
                    f"{name}: GlobalAssignment cleared less than greedy "
                    f"(recovered_ok!=True): {row.get('derived')!r}")
            base_rec, rec = _field(base_row, "recovered"), _field(row, "recovered")
            if base_rec and rec is not None and rec < base_rec * (1.0 - tol):
                failures.append(
                    f"{name}: recovered score {rec:.4f} vs baseline "
                    f"{base_rec:.4f} (-{(1 - rec / base_rec) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")
            if ("overhead_ok=" in base_row.get("derived", "")
                    and "overhead_ok=True" not in row.get("derived", "")):
                failures.append(
                    f"{name}: GlobalAssignment replay overhead regressed "
                    f"above the 9.34x serial baseline (overhead_ok!=True): "
                    f"{row.get('derived')!r}")
            for key, label in (("overhead", "serial replay overhead"),
                               ("overhead_batched", "batched replay overhead")):
                base_ov, ov = _field(base_row, key), _field(row, key)
                if base_ov and ov and ov > base_ov * (1.0 + tol):
                    failures.append(
                        f"{name}: {label} {ov:.2f}x vs baseline "
                        f"{base_ov:.2f}x (+{(ov / base_ov - 1) * 100:.0f}% > "
                        f"{tol * 100:.0f}% tolerance)")

        if name.startswith("fault_recovery_"):
            # crash-replay byte-identity is exact; goodput retained under
            # the fixed seeded fault plan is gated relative to baseline
            if ("crash_identical=" in base_row.get("derived", "")
                    and "crash_identical=True" not in row.get("derived", "")):
                failures.append(
                    f"{name}: crash-at-round-k replay no longer byte-"
                    f"identical to the uninterrupted run: "
                    f"{row.get('derived')!r}")
            base_gr, gr = (_field(base_row, "goodput_retained"),
                           _field(row, "goodput_retained"))
            if base_gr and gr is not None and gr < base_gr * (1.0 - tol):
                failures.append(
                    f"{name}: goodput retained under faults {gr:.3f} vs "
                    f"baseline {base_gr:.3f} (-{(1 - gr / base_gr) * 100:.0f}%"
                    f" > {tol * 100:.0f}% tolerance)")

        if name.startswith("service_latency_"):
            # soak determinism and the admission-control contract are
            # exact; p99 decision latency and goodput retained under 2x
            # overload are gated relative to baseline (simulated-time
            # metrics: machine speed cancels entirely)
            if ("deterministic=" in base_row.get("derived", "")
                    and "deterministic=True" not in row.get("derived", "")):
                failures.append(
                    f"{name}: fixed-seed soak no longer deterministic "
                    f"(award log or ServiceStats diverged): "
                    f"{row.get('derived')!r}")
            if ("overload_ok=" in base_row.get("derived", "")
                    and "overload_ok=True" not in row.get("derived", "")):
                failures.append(
                    f"{name}: admission-control contract broken (bounded "
                    f"queue no longer retains >=90% goodput at 2x overload "
                    f"with accept-all degrading below it): "
                    f"{row.get('derived')!r}")
            base_p99, p99 = _field(base_row, "p99"), _field(row, "p99")
            if base_p99 and p99 and p99 > base_p99 * (1.0 + tol):
                failures.append(
                    f"{name}: p99 decision latency {p99:.3f} vs baseline "
                    f"{base_p99:.3f} (+{(p99 / base_p99 - 1) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")
            base_gr, gr = (_field(base_row, "goodput_retained"),
                           _field(row, "goodput_retained"))
            if base_gr and gr is not None and gr < base_gr * (1.0 - tol):
                failures.append(
                    f"{name}: goodput retained under overload {gr:.3f} vs "
                    f"baseline {base_gr:.3f} "
                    f"(-{(1 - gr / base_gr) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")

        if name.startswith("repartition_"):
            # StaticInventory byte-identity and the goodput-recovery /
            # energy-saving contracts are exact; the recovered goodput and
            # the energy ratio are gated relative to baseline (simulated-
            # time metrics: machine speed cancels entirely)
            for flag, msg in (
                    ("static_identical",
                     "StaticInventory run diverged from the subsystem-off "
                     "run (byte-identity contract broken)"),
                    ("recovered_ok",
                     "FragmentationAware no longer recovers goodput over "
                     "the static fragmented inventory"),
                    ("energy_ok",
                     "EnergyAware no longer undercuts the static energy "
                     "proxy with all jobs finishing")):
                if (f"{flag}=" in base_row.get("derived", "")
                        and f"{flag}=True" not in row.get("derived", "")):
                    failures.append(f"{name}: {msg}: {row.get('derived')!r}")
            base_gp, gp = (_field(base_row, "goodput_frag_aware"),
                           _field(row, "goodput_frag_aware"))
            if base_gp and gp is not None and gp < base_gp * (1.0 - tol):
                failures.append(
                    f"{name}: recovered goodput {gp:.3f} vs baseline "
                    f"{base_gp:.3f} (-{(1 - gp / base_gp) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")
            base_er, er = (_field(base_row, "energy_ratio"),
                           _field(row, "energy_ratio"))
            if base_er and er is not None and er > base_er * (1.0 + tol):
                failures.append(
                    f"{name}: energy ratio {er:.3f} vs baseline "
                    f"{base_er:.3f} (+{(er / base_er - 1) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")

        if name.startswith("migration_"):
            # the revocation-ladder dominance contract and crash-replay
            # byte-identity across a migration boundary are exact; the
            # goodput retained vs drain-only and the work-saved fraction
            # are gated relative to baseline (simulated-time metrics:
            # machine speed cancels entirely)
            for flag, msg in (
                    ("ladder_ok",
                     "the revocation ladder no longer retains more goodput "
                     "than drain-only loss under the seeded revocations"),
                    ("crash_identical",
                     "crash-at-round-k replay across a migration boundary "
                     "no longer byte-identical to the uninterrupted run")):
                if (f"{flag}=" in base_row.get("derived", "")
                        and f"{flag}=True" not in row.get("derived", "")):
                    failures.append(f"{name}: {msg}: {row.get('derived')!r}")
            base_gr, gr = (_field(base_row, "goodput_retained"),
                           _field(row, "goodput_retained"))
            if base_gr and gr is not None and gr < base_gr * (1.0 - tol):
                failures.append(
                    f"{name}: ladder goodput retained {gr:.3f} vs baseline "
                    f"{base_gr:.3f} (-{(1 - gr / base_gr) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")
            base_ws, ws = (_field(base_row, "work_saved"),
                           _field(row, "work_saved"))
            if base_ws and ws is not None and ws < base_ws * (1.0 - tol):
                failures.append(
                    f"{name}: work saved from re-execution {ws:.3f} vs "
                    f"baseline {base_ws:.3f} "
                    f"(-{(1 - ws / base_ws) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")

        if name.startswith("adaptive_bidding_"):
            if "adaptive_ok=True" not in row.get("derived", ""):
                failures.append(
                    f"{name}: AdaptiveBidder cleared no more than "
                    f"GreedyChunking (adaptive_ok!=True): {row.get('derived')!r}")
            base_adv, adv = _field(base_row, "advantage"), _field(row, "advantage")
            if base_adv and adv is not None and adv < base_adv * (1.0 - tol):
                failures.append(
                    f"{name}: adaptive advantage {adv:.4f} vs baseline "
                    f"{base_adv:.4f} (-{(1 - adv / base_adv) * 100:.0f}% > "
                    f"{tol * 100:.0f}% tolerance)")

        if name.startswith("pipeline_overlap_"):
            if "identical_selections=True" not in row.get("derived", ""):
                failures.append(f"{name}: selections no longer identical")
            ratio = _field(row, "ratio")
            if ratio is None:
                failures.append(f"{name}: no ratio= field in derived output")
            elif ratio > max_overlap_ratio:
                failures.append(
                    f"{name}: pipelined/serial ratio {ratio:.2f} > "
                    f"{max_overlap_ratio} (pipelining regressed into a slowdown)")
            continue  # wall-clock depends on overlap; ratio is the gate

        base_us, us = base_row["us_per_call"], row["us_per_call"]
        if base_us > 0 and us > base_us * (1.0 + us_tol):
            failures.append(
                f"{name}: {us:.1f}us vs baseline {base_us:.1f}us "
                f"(+{(us / base_us - 1) * 100:.0f}% > {us_tol * 100:.0f}% headroom)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly produced BENCH_*.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_REGRESSION_TOL", 0.25)),
                    help="allowed relative-metric regression (0.25 = 25%%)")
    ap.add_argument("--us-tol", type=float,
                    default=float(os.environ.get("BENCH_US_TOL", 4.0)),
                    help="allowed absolute us_per_call headroom (4.0 = 5x; "
                         "calibrated to observed sandbox/runner jitter — "
                         "catches order-of-magnitude regressions like a "
                         "disabled jit cache, not machine drift)")
    ap.add_argument("--max-overlap-ratio", type=float,
                    default=float(os.environ.get("BENCH_MAX_OVERLAP_RATIO", 1.0)),
                    help="max allowed pipelined/serial wall-clock ratio")
    args = ap.parse_args()

    fresh, baseline = _load(args.fresh), _load(args.baseline)
    failures = check(fresh, baseline, args.tol, args.us_tol,
                     args.max_overlap_ratio)
    n_gated = sum(1 for n in baseline if n.startswith(GATED_PREFIXES))
    if failures:
        print(f"BENCH REGRESSION: {len(failures)} failure(s) over {n_gated} gated rows")
        for f in failures:
            print(f"  FAIL {f}")
        sys.exit(1)
    print(f"bench regression gate OK ({n_gated} gated rows, "
          f"tol {args.tol * 100:.0f}% relative / +{args.us_tol * 100:.0f}% absolute)")


if __name__ == "__main__":
    main()
